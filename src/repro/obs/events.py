"""Structured JSONL event sink + schema + summaries (DESIGN.md §11).

One run = one append-only ``events.jsonl``.  Every line is a JSON object
with the envelope fields ``ev`` (event type), ``t`` (unix seconds),
``run_id``; each event type adds its required payload (``SCHEMA`` below is
the single source of truth, and what CI's ``python -m repro.obs validate``
checks).  Telemetry metric names inside ``eval`` events must exist in the
``obs.telemetry`` catalogue — a typo'd metric is a schema error, not a
silently ignored key.

Feeding discipline: device code never calls into this module.  The sweep
engine returns telemetry with its ordinary ``eval_every``-thinned scan
outputs; ``record_sweep`` then writes them host-side after the compiled
call returns.  (That is why there is no "flush" anywhere near a scan.)
"""
from __future__ import annotations

import json
import os
import subprocess
import time
import uuid
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.obs import telemetry as T

SCHEMA_VERSION = 1

# required payload fields per event type (envelope ev/t/run_id implied)
SCHEMA: Dict[str, tuple] = {
    "run_start": ("config", "fingerprint", "git_sha"),
    "eval": ("cell", "iter", "loss", "bits", "dist"),
    "telemetry": ("cell", "iter", "metrics"),
    "span": ("name", "dur_s"),
    "train_step": ("step", "loss", "wall_s"),
    "wire": ("wire", "reduce_impl", "measured_bytes", "model_bytes"),
    "rollback": ("step", "count"),
    "note": ("text",),
    "bench": ("name", "value", "unit"),
    "run_end": ("status", "wall_s"),
}


def git_sha(repo: Optional[str] = None) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=repo,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _jsonable(x):
    if isinstance(x, (np.floating, np.integer)):
        return x.item()
    if isinstance(x, np.ndarray):
        return x.tolist()
    if hasattr(x, "tolist"):            # jax arrays land here
        return np.asarray(x).tolist()
    raise TypeError(f"not JSON-serializable: {type(x).__name__}")


def validate_event(rec: dict) -> List[str]:
    """Schema errors of one event record ([] == valid)."""
    errs = []
    ev = rec.get("ev")
    if ev not in SCHEMA:
        return [f"unknown event type {ev!r}"]
    for field in ("t", "run_id"):
        if field not in rec:
            errs.append(f"{ev}: missing envelope field {field!r}")
    for field in SCHEMA[ev]:
        if field not in rec:
            errs.append(f"{ev}: missing required field {field!r}")
    if ev in ("eval", "telemetry"):
        for name, v in (rec.get("metrics") or {}).items():
            if name not in T._CATALOGUE:
                errs.append(f"{ev}: metric {name!r} not in the catalogue")
            elif T.get(name).kind == "hist" and not isinstance(v, list):
                errs.append(f"{ev}: hist metric {name!r} must be a list")
            elif T.get(name).kind != "hist" and isinstance(v, list):
                errs.append(f"{ev}: scalar metric {name!r} got a list")
    return errs


class EventLog:
    """Append-only JSONL sink; validates on write, flushes per event.

    ``path=None`` makes an echo-only sink: events are validated and printed
    but not persisted — how drivers route their console output through the
    schema even when the user asked for no log file.
    """

    def __init__(self, path: Optional[str] = None,
                 run_id: Optional[str] = None, echo: bool = False):
        if path is not None:
            os.makedirs(os.path.dirname(os.path.abspath(path)),
                        exist_ok=True)
        self.path = path
        self.run_id = run_id or uuid.uuid4().hex[:12]
        self.echo = echo or path is None
        self._f = open(path, "a") if path is not None else None

    def emit(self, ev: str, **fields) -> dict:
        rec = {"ev": ev, "t": time.time(), "run_id": self.run_id, **fields}
        errs = validate_event(rec)
        if errs:
            raise ValueError(f"invalid {ev!r} event: {errs}")
        line = json.dumps(rec, default=_jsonable)
        if self._f is not None:
            self._f.write(line + "\n")
            self._f.flush()
        if self.echo:
            # the sanctioned console mirror — library code routes human
            # output through here instead of bare prints (astlint
            # print-in-library)
            print(line)        # repro-lint: allow=print-in-library
        return rec

    def start(self, config: dict, fingerprint: str = "",
              repo: Optional[str] = None, **extra) -> dict:
        return self.emit("run_start", config=config, fingerprint=fingerprint,
                         git_sha=git_sha(repo), schema=SCHEMA_VERSION,
                         **extra)

    def end(self, status: str = "ok", wall_s: float = 0.0, **extra) -> dict:
        return self.emit("run_end", status=status, wall_s=wall_s, **extra)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def read_events(path: str) -> List[dict]:
    out = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: bad JSON line: {e}") from e
    return out


def validate_events(events: Iterable[dict]) -> List[str]:
    errs = []
    for i, rec in enumerate(events):
        errs.extend(f"event {i}: {e}" for e in validate_event(rec))
    return errs


def record_sweep(log: EventLog, res, cfgs=None, labels=None,
                 every: int = 1) -> int:
    """Write a SweepResult's eval series (+ telemetry, if enabled) as
    ``eval`` events — host-side, after the compiled sweep returned.

    ``every`` thins the *event log* further (every-th eval point; the final
    point is always written).  Returns the number of events emitted.
    """
    V, G, S, E = res.losses.shape
    if labels is None:
        labels = ([f"{c.up}/{c.dwn}" + ("+ef" if c.error_feedback else "")
                   for c in cfgs] if cfgs is not None
                  else [f"v{v}" for v in range(V)])
    wrote = 0
    eidx = sorted(set(range(0, E, every)) | {E - 1})
    for v in range(V):
        for g in range(G):
            for s in range(S):
                for e in eidx:
                    metrics = None
                    if getattr(res, "telemetry", None):
                        metrics = {k: np.asarray(a[v, g, s, e]).tolist()
                                   for k, a in res.telemetry.items()}
                    log.emit(
                        "eval",
                        cell={"v": v, "g": g, "s": s, "label": labels[v]},
                        iter=int(res.eval_iters[e]),
                        loss=float(res.losses[v, g, s, e]),
                        bits=float(res.bits[v, g, s, e]),
                        dist=float(res.dists[v, g, s, e]),
                        **({"metrics": metrics} if metrics else {}))
                    wrote += 1
                rbs = int(np.asarray(res.rollbacks[v, g, s]))
                if rbs:
                    log.emit("rollback", step=int(res.eval_iters[-1]),
                             count=rbs,
                             cell={"v": v, "g": g, "s": s,
                                   "label": labels[v]})
                    wrote += 1
    return wrote


def _cell_key(rec: dict) -> tuple:
    c = rec["cell"]
    return (c.get("v", 0), c.get("g", 0), c.get("s", 0))


def summarize(events: List[dict]) -> dict:
    """Digest of one event log: run identity, per-cell final numbers,
    span totals, fault/rollback tallies, schema health."""
    by_type: Dict[str, int] = {}
    for rec in events:
        by_type[rec.get("ev", "?")] = by_type.get(rec.get("ev", "?"), 0) + 1
    start = next((r for r in events if r.get("ev") == "run_start"), None)
    end = next((r for r in reversed(events) if r.get("ev") == "run_end"),
               None)
    cells: Dict[tuple, dict] = {}
    for rec in events:
        if rec.get("ev") != "eval":
            continue
        k = _cell_key(rec)
        c = cells.setdefault(k, {"label": rec["cell"].get("label", ""),
                                 "evals": 0})
        c["evals"] += 1
        if c.get("iter", -1) <= rec["iter"]:     # last eval point wins
            c.update(iter=rec["iter"], loss=rec["loss"], bits=rec["bits"],
                     dist=rec["dist"])
            if "metrics" in rec:
                c["metrics"] = rec["metrics"]
    spans = {}
    for rec in events:
        if rec.get("ev") != "span":
            continue
        a = spans.setdefault(rec["name"], {"count": 0, "total_s": 0.0})
        a["count"] += 1
        a["total_s"] += rec["dur_s"]
    rollbacks = sum(r["count"] for r in events if r.get("ev") == "rollback")
    return {
        "run_id": events[0].get("run_id") if events else None,
        "git_sha": (start or {}).get("git_sha"),
        "fingerprint": (start or {}).get("fingerprint"),
        "status": (end or {}).get("status"),
        "wall_s": (end or {}).get("wall_s"),
        "events": by_type,
        "schema_errors": validate_events(events),
        "cells": {"/".join(map(str, k)): v for k, v in sorted(cells.items())},
        "spans": spans,
        "rollbacks": rollbacks,
    }
