"""BENCH_history.jsonl: an append-only benchmark ledger with a tolerance
regression gate (DESIGN.md §11).

The repo's ``BENCH_*.json`` files are snapshots each PR overwrites — useful
as documentation, useless as a gate.  This ledger is the complement: every
CI smoke run *appends* one line per benchmark metric (name, value, unit,
direction, tolerance, run-id, git sha), and ``check()`` fails the run when
the newest value regresses beyond tolerance against the best prior entry
in its window.  Deterministic metrics (compile counts, wire bytes, schema
errors) ride the same ledger with ``tol=0`` — any drift fails.

Directions: ``lower`` (timings, bytes, loss) and ``higher`` (throughput).
Tolerance is relative (0.25 == 25% worse than the best recent entry
fails); noise-prone wall-clock metrics should carry generous tolerances —
the gate is for order-of-magnitude rot, not microbenchmark jitter.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

from repro.obs.events import git_sha

DIRECTIONS = ("lower", "higher")
WINDOW = 20          # prior entries per metric considered by the gate


@dataclasses.dataclass(frozen=True)
class Verdict:
    name: str
    status: str              # 'ok' | 'regression' | 'baseline'
    latest: float
    best: Optional[float]    # best prior entry in the window (None: first)
    tol: float
    direction: str

    def describe(self) -> str:
        if self.status == "baseline":
            return f"{self.name}: baseline {self.latest:g}"
        rel = (0.0 if self.best in (None, 0.0)
               else (self.latest - self.best) / abs(self.best))
        return (f"{self.name}: {self.status} latest={self.latest:g} "
                f"best={self.best:g} ({rel:+.1%}, tol {self.tol:.0%} "
                f"{self.direction})")


def append(path: str, name: str, value: float, unit: str, *,
           direction: str = "lower", tol: float = 0.25,
           run_id: str = "", meta: Optional[dict] = None) -> dict:
    if direction not in DIRECTIONS:
        raise ValueError(f"direction {direction!r} not in {DIRECTIONS}")
    entry = {"name": name, "value": float(value), "unit": unit,
             "direction": direction, "tol": float(tol), "t": time.time(),
             "run_id": run_id, "git_sha": git_sha()}
    if meta:
        entry["meta"] = meta
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")
    return entry


def load(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: bad ledger line: {e}") from e
    return out


def check(path: str, names: Optional[List[str]] = None,
          window: int = WINDOW) -> List[Verdict]:
    """Gate the newest entry of each metric against the best of its prior
    ``window`` entries.  Returns one Verdict per metric (file order)."""
    by_name: Dict[str, List[dict]] = {}
    for e in load(path):
        by_name.setdefault(e["name"], []).append(e)
    verdicts = []
    for name, entries in by_name.items():
        if names is not None and name not in names:
            continue
        latest = entries[-1]
        prior = entries[:-1][-window:]
        direction = latest.get("direction", "lower")
        tol = float(latest.get("tol", 0.25))
        if not prior:
            verdicts.append(Verdict(name, "baseline", latest["value"], None,
                                    tol, direction))
            continue
        vals = [p["value"] for p in prior]
        best = min(vals) if direction == "lower" else max(vals)
        if direction == "lower":
            bad = latest["value"] > best * (1.0 + tol) + 1e-12
        else:
            bad = latest["value"] < best * (1.0 - tol) - 1e-12
        verdicts.append(Verdict(name, "regression" if bad else "ok",
                                latest["value"], best, tol, direction))
    return verdicts


def regressions(path: str, window: int = WINDOW) -> List[Verdict]:
    return [v for v in check(path, window=window) if v.status == "regression"]
