"""CLI for the observability layer (DESIGN.md §11).

  PYTHONPATH=src python -m repro.obs summary  events.jsonl
  PYTHONPATH=src python -m repro.obs validate events.jsonl
  PYTHONPATH=src python -m repro.obs diff     a.jsonl b.jsonl
  PYTHONPATH=src python -m repro.obs dashboard events.jsonl [-o dashboard.md]
  PYTHONPATH=src python -m repro.obs bench-append LEDGER NAME VALUE UNIT ...
  PYTHONPATH=src python -m repro.obs bench-check  LEDGER
  PYTHONPATH=src python -m repro.obs smoke -o obs_out/   # instrumented sweep

``smoke`` is CI stage 5's entry point: it runs a small instrumented sweep
grid (telemetry on, one faulted variant), captures a Perfetto trace, writes
a schema-valid ``events.jsonl`` + ``dashboard.md``, and appends to the
``BENCH_history.jsonl`` ledger.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.obs import bench, events, spans
from repro.obs import telemetry as T

_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(xs, width: int = 40) -> str:
    """Unicode sparkline of a series, log-scaled when it spans decades."""
    xs = np.asarray(xs, np.float64)
    xs = xs[np.isfinite(xs)]
    if xs.size == 0:
        return "(no finite data)"
    if xs.size > width:
        idx = np.linspace(0, xs.size - 1, width).round().astype(int)
        xs = xs[idx]
    pos = xs[xs > 0]
    if pos.size and pos.max() / max(pos.min(), 1e-300) > 1e3:
        xs = np.log10(np.maximum(xs, pos.min()))
    lo, hi = xs.min(), xs.max()
    if hi - lo < 1e-12:
        return _BLOCKS[0] * xs.size
    q = ((xs - lo) / (hi - lo) * (len(_BLOCKS) - 1)).round().astype(int)
    return "".join(_BLOCKS[i] for i in q)


def _fmt(v, nd=4):
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


# ---------------------------------------------------------------------------
# subcommands
# ---------------------------------------------------------------------------

def cmd_summary(args) -> int:
    s = events.summarize(events.read_events(args.log))
    if args.json:
        print(json.dumps(s, indent=2, default=str))
        return 0
    print(f"run {s['run_id']}  sha {s['git_sha']}  status {s['status']}"
          f"  wall {_fmt(s['wall_s'])}s")
    print("events: " + ", ".join(f"{k}={v}"
                                 for k, v in sorted(s["events"].items())))
    if s["rollbacks"]:
        print(f"rollbacks: {s['rollbacks']}")
    for key, c in s["cells"].items():
        line = (f"cell {key} [{c['label']}] iter {c.get('iter')} "
                f"loss {_fmt(c.get('loss'))} bits {_fmt(c.get('bits'))} "
                f"dist {_fmt(c.get('dist'))}")
        m = c.get("metrics") or {}
        extras = [f"{n}={_fmt(m[n])}" for n in
                  ("active", "mem_drift", "err_up", "rollbacks") if n in m]
        if extras:
            line += "  |  " + " ".join(extras)
        print(line)
    for name, a in sorted(s["spans"].items(), key=lambda kv: -kv[1]["total_s"]):
        print(f"span {name}: {a['count']}x  total {a['total_s']:.3f}s")
    errs = s["schema_errors"]
    if errs:
        print(f"SCHEMA ERRORS ({len(errs)}):")
        for e in errs[:20]:
            print("  " + e)
        return 1
    return 0


def cmd_validate(args) -> int:
    evs = events.read_events(args.log)
    errs = events.validate_events(evs)
    for e in errs:
        print(e)
    print(f"{args.log}: {len(evs)} events, {len(errs)} schema errors")
    return 1 if errs else 0


def cmd_diff(args) -> int:
    sa = events.summarize(events.read_events(args.a))
    sb = events.summarize(events.read_events(args.b))
    print(f"A: run {sa['run_id']} sha {sa['git_sha']}  "
          f"B: run {sb['run_id']} sha {sb['git_sha']}")
    keys = sorted(set(sa["cells"]) | set(sb["cells"]))
    rc = 0
    for k in keys:
        ca, cb = sa["cells"].get(k), sb["cells"].get(k)
        if ca is None or cb is None:
            print(f"cell {k}: only in {'B' if ca is None else 'A'}")
            rc = 1
            continue
        for f in ("loss", "bits", "dist"):
            va, vb = ca.get(f), cb.get(f)
            if va is None or vb is None:
                continue
            rel = 0.0 if va == vb else (vb - va) / max(abs(va), 1e-30)
            mark = ""
            if abs(rel) > args.tol:
                mark = "  <-- drift"
                rc = 1
            print(f"cell {k} [{ca['label']}] {f}: {_fmt(va)} -> {_fmt(vb)} "
                  f"({rel:+.2%}){mark}")
    for name in sorted(set(sa["spans"]) | set(sb["spans"])):
        ta = sa["spans"].get(name, {}).get("total_s", 0.0)
        tb = sb["spans"].get(name, {}).get("total_s", 0.0)
        print(f"span {name}: {ta:.3f}s -> {tb:.3f}s")
    return rc


def render_dashboard(evs) -> str:
    """Markdown dashboard: loss curves, the paper's bits-vs-accuracy
    frontier, wire model-vs-measured, span table."""
    s = events.summarize(evs)
    series = {}
    for rec in evs:
        if rec.get("ev") != "eval":
            continue
        series.setdefault(events._cell_key(rec), []).append(rec)
    out = [f"# repro.obs dashboard — run `{s['run_id']}`",
           "",
           f"* git sha: `{s['git_sha']}`  status: **{s['status']}**  "
           f"wall: {_fmt(s['wall_s'])}s",
           f"* events: " + ", ".join(f"{k}={v}" for k, v
                                     in sorted(s["events"].items())),
           f"* schema errors: {len(s['schema_errors'])}  "
           f"rollbacks: {s['rollbacks']}", ""]

    out += ["## Loss curves (per grid cell)", "",
            "| cell | variant | final loss | curve |",
            "|---|---|---:|---|"]
    for k in sorted(series):
        rs = sorted(series[k], key=lambda r: r["iter"])
        xs = [r["loss"] for r in rs]
        out.append(f"| {'/'.join(map(str, k))} | {rs[-1]['cell'].get('label')}"
                   f" | {_fmt(xs[-1])} | `{sparkline(xs)}` |")

    out += ["", "## Bits vs. accuracy frontier", "",
            "The paper's Fig. 2-style comparison: total communicated bits "
            "against the loss they bought (final eval point, per cell, "
            "sorted by bits).", "",
            "| variant | cell | total bits | final loss | final dist |",
            "|---|---|---:|---:|---:|"]
    rows = []
    for k in sorted(series):
        r = max(series[k], key=lambda r: r["iter"])
        rows.append((r["bits"], r["cell"].get("label"),
                     "/".join(map(str, k)), r["loss"], r["dist"]))
    for bits, label, cell, loss, dist in sorted(rows):
        out.append(f"| {label} | {cell} | {bits:.3g} | {_fmt(loss)} "
                   f"| {_fmt(dist)} |")

    tel_rows = [(k, max(series[k], key=lambda r: r["iter"]).get("metrics"))
                for k in sorted(series)]
    tel_rows = [(k, m) for k, m in tel_rows if m]
    if tel_rows:
        names = [n for n in ("active", "straggler_drops", "blowup_hits",
                             "wire_scrubbed", "err_up", "mem_drift",
                             "rollbacks") if n in tel_rows[0][1]]
        out += ["", "## Telemetry (final eval point)", "",
                "| cell | " + " | ".join(names) + " |",
                "|---|" + "---:|" * len(names)]
        for k, m in tel_rows:
            out.append("| " + "/".join(map(str, k)) + " | "
                       + " | ".join(_fmt(m[n]) for n in names) + " |")

    wires = [r for r in evs if r.get("ev") == "wire"]
    if wires:
        out += ["", "## Wire bytes: model vs. measured", "",
                "| wire | reduce | model B/step | measured B/step | rel err |",
                "|---|---|---:|---:|---:|"]
        for r in wires:
            mo, me = r["model_bytes"], r["measured_bytes"]
            rel = abs(me - mo) / max(abs(mo), 1e-30)
            out.append(f"| {r['wire']} | {r['reduce_impl']} | {mo:.0f} "
                       f"| {me:.0f} | {rel:.2%} |")

    if s["spans"]:
        out += ["", "## Spans", "", "| span | count | total s |",
                "|---|---:|---:|"]
        for name, a in sorted(s["spans"].items(),
                              key=lambda kv: -kv[1]["total_s"]):
            out.append(f"| {name} | {a['count']} | {a['total_s']:.3f} |")
    return "\n".join(out) + "\n"


def cmd_dashboard(args) -> int:
    md = render_dashboard(events.read_events(args.log))
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"wrote {args.out}")
    else:
        print(md)
    return 0


def cmd_bench_append(args) -> int:
    e = bench.append(args.ledger, args.name, args.value, args.unit,
                     direction=args.direction, tol=args.tol,
                     run_id=args.run_id)
    print(json.dumps(e))
    return 0


def cmd_bench_check(args) -> int:
    verdicts = bench.check(args.ledger, window=args.window)
    bad = 0
    for v in verdicts:
        print(v.describe())
        bad += v.status == "regression"
    print(f"{args.ledger}: {len(verdicts)} metrics, {bad} regressions")
    return 1 if bad else 0


def cmd_smoke(args) -> int:
    """Instrumented end-to-end smoke: sweep grid with telemetry + a faulted
    variant, Perfetto capture, JSONL log, dashboard, bench ledger."""
    import os

    import jax

    from repro.core import artemis as art
    from repro.core import faults as F
    from repro.core import federated as fed
    from repro.core import sweep as S

    os.makedirs(args.out, exist_ok=True)
    log_path = os.path.join(args.out, "events.jsonl")
    t_start = time.perf_counter()
    prob, w_star = fed.make_lsr_problem(jax.random.PRNGKey(12),
                                        n_workers=10, n_per=60, d=20,
                                        noise=0.0)
    fc = F.FaultConfig(blowup_rate=0.05, blowup_value=float("nan"),
                       scrub=True, straggler_rate=0.1,
                       sentinel=1e6, backoff=0.5)
    mk = lambda **kw: art.ArtemisConfig(dim=prob.dim,
                                        n_workers=prob.n_workers, **kw)
    cfgs = [mk(up="identity", dwn="identity", alpha=0.0),
            mk(up="squant", dwn="squant", up_kwargs={"s": 1},
               dwn_kwargs={"s": 1}),
            mk(up="squant", dwn="squant", up_kwargs={"s": 1},
               dwn_kwargs={"s": 1}, p=0.5, faults=fc)]
    labels = ["sgd-uncompressed", "artemis-1bit", "artemis-1bit-faulted"]

    spans.reset()
    with events.EventLog(log_path, echo=args.echo) as log:
        spans.install_sink(log)
        try:
            log.start(config={"iters": args.iters, "eval_every": args.every,
                              "grid": labels, "gamma": args.gamma},
                      fingerprint=f"obs-smoke-d{prob.dim}")
            trace_dir = os.path.join(args.out, "trace")
            with spans.profile(trace_dir):
                with spans.span("obs/sweep"):
                    res = S.run_sweep(prob, cfgs, [args.gamma], [0, 1],
                                      args.iters, eval_every=args.every,
                                      w_star=w_star, telemetry=True)
            n_ev = events.record_sweep(log, res, cfgs=cfgs, labels=labels)
            wall = time.perf_counter() - t_start
            log.end(status="ok", wall_s=wall, traces=res.traces,
                    eval_events=n_ev)
        finally:
            spans.uninstall_sink()

    evs = events.read_events(log_path)
    errs = events.validate_events(evs)
    md = render_dashboard(evs)
    dash = os.path.join(args.out, "dashboard.md")
    with open(dash, "w") as f:
        f.write(md)
    arts = spans.perfetto_artifacts(trace_dir)

    if args.ledger:
        run_id = evs[0]["run_id"]
        tel = res.telemetry
        faulted_bits = float(res.bits[2, 0, 0, -1])
        bench.append(args.ledger, "obs_smoke.wall_s",
                     time.perf_counter() - t_start, "s", tol=1.0,
                     run_id=run_id)
        bench.append(args.ledger, "obs_smoke.traces", res.traces, "compiles",
                     tol=0.0, run_id=run_id)
        bench.append(args.ledger, "obs_smoke.schema_errors", len(errs),
                     "errors", tol=0.0, run_id=run_id)
        bench.append(args.ledger, "obs_smoke.final_loss_1bit",
                     float(res.losses[1, 0, 0, -1]), "nll", tol=0.05,
                     run_id=run_id)
        bench.append(args.ledger, "obs_smoke.bits_faulted", faulted_bits,
                     "bits", tol=0.05, run_id=run_id)
        bench.append(args.ledger, "obs_smoke.blowup_hits",
                     float(tel["blowup_hits"][2, 0, 0, -1]), "workers",
                     tol=0.0, run_id=run_id)

    print(f"events: {log_path} ({len(evs)} events, {len(errs)} schema "
          f"errors)")
    print(f"dashboard: {dash}")
    print(f"perfetto: {arts[0] if arts else 'MISSING'}")
    if errs or not arts:
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summary", help="digest one event log")
    p.add_argument("log")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_summary)

    p = sub.add_parser("validate", help="schema-check one event log")
    p.add_argument("log")
    p.set_defaults(fn=cmd_validate)

    p = sub.add_parser("diff", help="compare two runs' event logs")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--tol", type=float, default=0.05,
                   help="relative drift that counts as a difference")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("dashboard", help="render the markdown dashboard")
    p.add_argument("log")
    p.add_argument("-o", "--out", default=None)
    p.set_defaults(fn=cmd_dashboard)

    p = sub.add_parser("bench-append", help="append one ledger entry")
    p.add_argument("ledger")
    p.add_argument("name")
    p.add_argument("value", type=float)
    p.add_argument("unit")
    p.add_argument("--direction", default="lower",
                   choices=list(bench.DIRECTIONS))
    p.add_argument("--tol", type=float, default=0.25)
    p.add_argument("--run-id", default="")
    p.set_defaults(fn=cmd_bench_append)

    p = sub.add_parser("bench-check", help="regression-gate the ledger")
    p.add_argument("ledger")
    p.add_argument("--window", type=int, default=bench.WINDOW)
    p.set_defaults(fn=cmd_bench_check)

    p = sub.add_parser("smoke", help="instrumented smoke sweep (CI stage 5)")
    p.add_argument("-o", "--out", default="obs_out")
    p.add_argument("--iters", type=int, default=200)
    p.add_argument("--every", type=int, default=10)
    p.add_argument("--gamma", type=float, default=0.05)
    p.add_argument("--ledger", default=None,
                   help="BENCH_history.jsonl to append to")
    p.add_argument("--echo", action="store_true")
    p.set_defaults(fn=cmd_smoke)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
