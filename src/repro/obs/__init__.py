"""repro.obs — trace-safe telemetry, profiling spans, structured event
logs, and the benchmark-regression ledger (DESIGN.md §11).

Four pieces, one vocabulary:

  * ``obs.telemetry`` — the metric catalogue and the pure-pytree in-trace
    carry the sweep/mesh engines thread through their scans (statically
    gated: disabled == byte-identical trace);
  * ``obs.events``    — the host-side JSONL event sink + schema +
    ``summarize`` (fed at ``eval_every`` points, never from device code);
  * ``obs.spans``     — ``span()`` wall-clock + ``jax.profiler`` wrappers
    and Perfetto capture (``profile`` / ``perfetto_artifacts``);
  * ``obs.bench``     — the append-only ``BENCH_history.jsonl`` ledger and
    its tolerance regression gate for CI.

CLI: ``python -m repro.obs {summary,validate,diff,dashboard,bench-append,
bench-check,smoke} ...``
"""
from repro.obs import bench, events, spans, telemetry            # noqa: F401
from repro.obs.events import EventLog, read_events, summarize    # noqa: F401
from repro.obs.spans import profile, span                        # noqa: F401
