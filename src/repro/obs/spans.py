"""Host-side profiling spans + Perfetto trace capture (DESIGN.md §11).

``span("name")`` wraps a host-side region in both a wall-clock timer
(``time.perf_counter``) and a ``jax.profiler.TraceAnnotation``, so the same
name shows up (a) in the in-process span ledger this module keeps, (b) in
the JSONL event log when a sink is installed, and (c) on the Perfetto
timeline when a ``profile(...)`` capture is active.  The launch layer wraps
compile vs. execute, the sweep CLI wraps lower/compile/run, and the mesh
benchmarks wrap ring steps — one vocabulary everywhere.

These are HOST spans: they never appear inside a traced function.  For
in-trace annotation (visible in XLA op names / the profiler's device
timeline, metadata-only and DCE-safe) use ``jax.named_scope`` directly —
``core/dist.py`` and the kernels do.

``profile(log_dir)`` wraps ``jax.profiler.trace`` and returns the
``.trace.json.gz`` artifacts it produced (Perfetto/Chrome ``chrome://
tracing`` compatible) via ``perfetto_artifacts``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import pathlib
import time
from typing import List, Optional

import jax

_MAX_SPANS = 4096


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    name: str
    t0: float        # perf_counter at entry (monotonic; deltas only)
    dur_s: float
    depth: int       # nesting level at entry


_SPANS: List[SpanRecord] = []
_DEPTH = [0]
_SINK = [None]      # optional EventLog; list cell so tests can swap it


def install_sink(log) -> None:
    """Mirror every closed span into ``log`` (an ``events.EventLog``)."""
    _SINK[0] = log


def uninstall_sink() -> None:
    _SINK[0] = None


def reset() -> None:
    _SPANS.clear()


def records() -> List[SpanRecord]:
    return list(_SPANS)


def total(name: str) -> float:
    """Summed duration of every closed span called ``name`` (seconds)."""
    return sum(r.dur_s for r in _SPANS if r.name == name)


@contextlib.contextmanager
def span(name: str, **attrs):
    """Time a host-side region; mirrors into the profiler timeline + sink."""
    t0 = time.perf_counter()
    _DEPTH[0] += 1
    try:
        with jax.profiler.TraceAnnotation(name):
            yield
    finally:
        _DEPTH[0] -= 1
        dur = time.perf_counter() - t0
        if len(_SPANS) >= _MAX_SPANS:      # bounded: drop oldest
            del _SPANS[: _MAX_SPANS // 2]
        _SPANS.append(SpanRecord(name, t0, dur, _DEPTH[0]))
        if _SINK[0] is not None:
            _SINK[0].emit("span", name=name, dur_s=dur, depth=_DEPTH[0],
                          **attrs)


@contextlib.contextmanager
def profile(log_dir: str):
    """Capture a Perfetto/Chrome trace of the wrapped region into
    ``log_dir`` (``jax.profiler.trace``); yields the directory path."""
    with jax.profiler.trace(str(log_dir)):
        yield log_dir


def perfetto_artifacts(log_dir: str) -> List[str]:
    """The ``.trace.json.gz`` files a ``profile`` capture wrote (possibly
    several across nested date directories), newest first."""
    root = pathlib.Path(log_dir)
    if not root.is_dir():
        return []
    hits = sorted(root.rglob("*.trace.json.gz"),
                  key=lambda p: p.stat().st_mtime, reverse=True)
    return [str(p) for p in hits]


def compile_execute_split(fn, *args) -> dict:
    """Time ``fn``'s first call (trace+compile+run) vs. a steady-state call,
    under the spans ``obs/compile`` and ``obs/execute``.  Returns the two
    durations; the caller reuses ``fn``'s warm executable afterwards."""
    with span("obs/compile"):
        out = jax.block_until_ready(fn(*args))
    with span("obs/execute"):
        out = jax.block_until_ready(fn(*args))
    del out
    return {"compile_s": _SPANS[-2].dur_s - _SPANS[-1].dur_s,
            "first_call_s": _SPANS[-2].dur_s,
            "execute_s": _SPANS[-1].dur_s}


def summarize_spans(recs: Optional[List[SpanRecord]] = None) -> List[dict]:
    """Aggregate by name: count, total, mean, max (sorted by total desc)."""
    recs = _SPANS if recs is None else recs
    agg = {}
    for r in recs:
        a = agg.setdefault(r.name, {"name": r.name, "count": 0,
                                    "total_s": 0.0, "max_s": 0.0})
        a["count"] += 1
        a["total_s"] += r.dur_s
        a["max_s"] = max(a["max_s"], r.dur_s)
    for a in agg.values():
        a["mean_s"] = a["total_s"] / a["count"]
    return sorted(agg.values(), key=lambda a: -a["total_s"])
