"""In-trace telemetry: a pure-pytree metrics carry (DESIGN.md §11).

The quantities that drive the paper's convergence story — compression-error
variance at the optimum (Assumption 5), the memory-drift term
``||h_i - grad F_i(w*)||`` that controls the linear-rate threshold, the
Remark-3 bit ledger, participation/fault counts — are all *inside* the
compiled programs (``core/sweep.py`` grids, ``core/dist.py`` mesh steps).
This module gives every layer one way to surface them:

  * a **metric catalogue** (``Metric`` descriptors registered in
    ``CATALOGUE``) naming each counter/gauge/histogram once, with kind,
    unit, and doc — the JSONL event schema, the dashboard, and DESIGN.md
    §11 all derive from it;
  * a **telemetry carry**: a flat ``{name: jnp.float32 array}`` dict that
    rides inside ``lax.scan`` carries like any other pytree.  Counters
    accumulate monotonically, stride gauges accumulate a sum that the eval
    point divides by the stride, histograms accumulate fixed-edge bucket
    counts (static edges — nothing data-dependent in the trace).

Discipline (load-bearing, pinned by tests/test_obs.py):

  * telemetry is **statically gated** — a disabled config never constructs
    the carry, so the trace (and therefore the trajectory, bit-for-bit, and
    the compile count) is byte-identical to a build that predates this
    module;
  * everything here is pure pytree arithmetic — **no host callbacks** ever
    run inside the hot scan; values come back with the ordinary scan
    outputs at ``eval_every`` points and are written to the JSONL sink
    (``obs/events.py``) on the host afterwards.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

KINDS = ("counter", "gauge", "hist")


@dataclasses.dataclass(frozen=True)
class Metric:
    """One registered metric: the unit of the catalogue and the schema."""
    name: str
    kind: str                 # 'counter' | 'gauge' | 'hist'
    doc: str
    unit: str = ""
    edges: Optional[Tuple[float, ...]] = None   # hist bucket edges (static)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"metric kind {self.kind!r} not in {KINDS}")
        if (self.kind == "hist") != (self.edges is not None):
            raise ValueError(f"metric {self.name!r}: hist <=> edges")


_CATALOGUE: Dict[str, Metric] = {}


def register(m: Metric) -> Metric:
    if m.name in _CATALOGUE and _CATALOGUE[m.name] != m:
        raise ValueError(f"metric {m.name!r} already registered differently")
    _CATALOGUE[m.name] = m
    return m


def catalogue() -> Tuple[Metric, ...]:
    """All registered metrics, registration-ordered (dicts preserve it)."""
    return tuple(_CATALOGUE.values())


def get(name: str) -> Metric:
    return _CATALOGUE[name]


# log10-spaced edges for squared-error histograms: bucket 0 is underflow
# (< 1e-12), the last bucket catches overflow and NaN (searchsorted sends
# NaN past every edge because every comparison is False)
ERR_EDGES = tuple(float(10.0 ** e) for e in range(-12, 7, 2))


def hist_zeros(m: Metric) -> jnp.ndarray:
    return jnp.zeros((len(m.edges) + 1,), jnp.float32)


def hist_add(counts: jnp.ndarray, m: Metric, value) -> jnp.ndarray:
    """Bucket one scalar observation into fixed-edge counts (in-trace)."""
    idx = jnp.searchsorted(jnp.asarray(m.edges, jnp.float32),
                           jnp.asarray(value, jnp.float32))
    return counts.at[idx].add(1.0)


def hist_edges_list(m: Metric):
    return [float(e) for e in m.edges]


# ---------------------------------------------------------------------------
# sweep-engine telemetry (core/sweep.py; one entry per round, emit per eval)
# ---------------------------------------------------------------------------

SWEEP_COUNTERS = tuple(register(Metric(n, "counter", d, unit=u)).name
                       for n, u, d in [
    ("avail", "workers", "availability draws that came up active "
                         "(pre-straggler Bernoulli/Markov mask)"),
    ("active", "workers", "workers that actually completed the round "
                          "(post straggler drop + entry scrub)"),
    ("straggler_drops", "workers", "available workers that missed the "
                                   "round deadline"),
    ("blowup_hits", "workers", "gradients replaced by blowup_value by the "
                               "fault injector"),
    ("entry_scrub_drops", "workers", "workers masked inactive because their "
                                     "gradient arrived non-finite"),
    ("wire_scrubbed", "payloads", "uplink payloads dropped by the server "
                                  "checksum (codec.validate)"),
    ("uplink_bits", "bits", "paper-side Elias-coded uplink cost "
                            "(DESIGN.md §4)"),
    ("dwnlink_bits", "bits", "paper-side downlink broadcast cost"),
    ("catchup_bits", "bits", "Remark-3 catch-up downloads of returning "
                             "workers"),
])

SWEEP_GAUGES = tuple(register(Metric(n, "gauge", d, unit=u)).name
                     for n, u, d in [
    ("err_up", "norm^2", "mean per-worker uplink compression error "
                         "||Delta_hat - Delta||^2 (Assumption 5), stride "
                         "mean"),
    ("err_dwn", "norm^2", "downlink compression error ||omega - ghat||^2, "
                          "stride mean"),
    ("ghat_norm", "norm", "server aggregate norm ||ghat||, stride mean"),
])

SWEEP_EVAL_GAUGES = tuple(register(Metric(n, "gauge", d, unit=u)).name
                          for n, u, d in [
    ("mem_drift", "norm", "mean_i ||h_i - grad F_i(w*)|| — the memory-"
                          "drift term of the linear-rate threshold "
                          "(sampled at eval points; w*=0 when no w_star "
                          "was passed)"),
    ("e_norm", "norm", "mean error-feedback buffer norm ||e_i|| (zero "
                       "unless Dore/EF)"),
    ("rollbacks", "count", "divergence-sentinel rollbacks so far "
                           "(cumulative at eval points)"),
])

ERR_UP_HIST = register(Metric(
    "err_up_hist", "hist",
    "distribution of per-round uplink compression error (log10 buckets; "
    "first bucket underflow, last bucket overflow/NaN)",
    unit="rounds", edges=ERR_EDGES))

SWEEP_METRICS = SWEEP_COUNTERS + SWEEP_GAUGES + SWEEP_EVAL_GAUGES + (
    ERR_UP_HIST.name,)

# Carry representation: ONE packed f32 vector for every scalar slot
# (counters first, then stride-gauge sums) plus the histogram. A dict of
# 12 scalar carries costs ~12 extra ops per scan iteration — pure dispatch
# overhead that showed up as ~20% on CPU microbenchmarks; one [12] vector
# add is ~3 ops regardless of how many metrics ride along. The packed
# layout is private: sweep_round feeds it, sweep_emit unpacks to names.
_PACK = SWEEP_COUNTERS + SWEEP_GAUGES
_PACK_IDX = {n: i for i, n in enumerate(_PACK)}
# reset multiplier: keep counters (1), zero stride-gauge sums (0)
_STRIDE_KEEP = np.asarray([0.0 if n in SWEEP_GAUGES else 1.0
                           for n in _PACK], np.float32)


def sweep_zeros() -> Dict[str, jnp.ndarray]:
    """Fresh telemetry carry for one sweep cell (vmap batches it)."""
    return {"pack": jnp.zeros((len(_PACK),), jnp.float32),
            ERR_UP_HIST.name: hist_zeros(ERR_UP_HIST)}


def sweep_round(**values) -> jnp.ndarray:
    """One round's raw readings as the packed vector (every lax.switch
    branch must return the same structure, so missing entries default to
    zero)."""
    return jnp.stack([jnp.asarray(values.get(n, 0.0), jnp.float32)
                      for n in _PACK])


def sweep_accumulate(acc: Dict[str, jnp.ndarray],
                     tel: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    return {"pack": acc["pack"] + tel,
            ERR_UP_HIST.name: hist_add(acc[ERR_UP_HIST.name], ERR_UP_HIST,
                                       tel[_PACK_IDX["err_up"]])}


def sweep_emit(acc: Dict[str, jnp.ndarray], eval_every: int,
               **eval_gauges) -> Dict[str, jnp.ndarray]:
    """The per-eval-point reading, unpacked to metric names: cumulative
    counters + hist, stride-mean gauges, plus eval-time gauges
    (mem_drift/e_norm/rollbacks)."""
    pack = acc["pack"]
    out = {c: pack[_PACK_IDX[c]] for c in SWEEP_COUNTERS}
    for g in SWEEP_GAUGES:
        out[g] = pack[_PACK_IDX[g]] / float(eval_every)
    out[ERR_UP_HIST.name] = acc[ERR_UP_HIST.name]
    for g in SWEEP_EVAL_GAUGES:
        out[g] = jnp.asarray(eval_gauges.get(g, 0.0), jnp.float32)
    return out


def sweep_reset_stride(acc: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Zero the stride-mean sums after an eval emission (counters and the
    histogram stay cumulative)."""
    return {"pack": acc["pack"] * jnp.asarray(_STRIDE_KEEP),
            ERR_UP_HIST.name: acc[ERR_UP_HIST.name]}


# ---------------------------------------------------------------------------
# mesh-backend telemetry (core/dist.py; per-step scalars, no carry needed)
# ---------------------------------------------------------------------------

MESH_METRICS = tuple(register(Metric(n, "gauge", d, unit=u)).name
                     for n, u, d in [
    ("wire_bytes", "bytes", "physical payload bytes this worker moved on "
                            "the inter-worker wire this step (hops x "
                            "codec.wire_bytes; reconciles against "
                            "launch/roofline wire models)"),
    ("mesh_active", "frac", "participation mask of this round (pmean over "
                            "workers = participating fraction)"),
    ("mesh_scrubbed", "payloads", "payload units (buckets/leaves) dropped "
                                  "by the server checksum this step"),
    ("mesh_blowup_hits", "count", "gradient blowups injected this step"),
])


def mesh_zeros() -> Dict[str, jnp.ndarray]:
    return {m: jnp.zeros((), jnp.float32) for m in MESH_METRICS}


def tree_to_numpy(tel) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in tel.items()}
