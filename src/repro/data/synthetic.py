"""Synthetic token streams for LM training (deterministic, host-shardable).

A Zipf-distributed Markov-ish token source with enough structure for the loss
to visibly drop within a few hundred steps: token t+1 is drawn from a mixture
of a global Zipf prior and a deterministic successor of token t — models must
learn the bigram table to win.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStreamConfig:
    vocab: int
    seq_len: int
    batch: int
    bigram_weight: float = 0.65   # how predictable the stream is
    zipf_a: float = 1.2
    seed: int = 0


class TokenStream:
    """Deterministic synthetic corpus; ``batch_at(step)`` is pure in (step)."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.prior = (ranks ** -cfg.zipf_a)
        self.prior /= self.prior.sum()
        # a fixed random permutation as the "grammar" (bigram successor table)
        self.successor = rng.permutation(v).astype(np.int32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s, v = cfg.batch, cfg.seq_len, cfg.vocab
        out = np.empty((b, s), np.int32)
        cur = rng.choice(v, size=b, p=self.prior)
        out[:, 0] = cur
        noise = rng.random((b, s))
        fresh = rng.choice(v, size=(b, s), p=self.prior)
        for t in range(1, s):
            follow = noise[:, t] < cfg.bigram_weight
            cur = np.where(follow, self.successor[cur], fresh[:, t])
            out[:, t] = cur
        return {"tokens": jnp.asarray(out)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def bigram_entropy(cfg: TokenStreamConfig) -> float:
    """Achievable NLL floor (nats/token) for a model that learns the bigram."""
    w = cfg.bigram_weight
    prior = TokenStream(cfg).prior
    h_prior = -float(np.sum(prior * np.log(prior)))
    # mixture: w on successor, (1-w) from prior
    h = -(w * np.log(w + (1 - w) * prior.mean()))   # rough bound
    return float(min(h + (1 - w) * h_prior, h_prior))
