"""Sharded batch pipeline: host-local numpy generation -> global jax.Array.

On a real multi-host cluster each process generates only its addressable
shard (``process_index``-keyed slice of the global batch) and the global
array is assembled with ``jax.make_array_from_process_local_data``; in this
single-process container that degenerates to a device_put with the requested
sharding, exercising the same code path.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.synthetic import TokenStream, TokenStreamConfig


class ShardedBatches:
    def __init__(self, stream: TokenStream, mesh: Optional[Mesh] = None,
                 batch_axes=("pod", "data")):
        self.stream = stream
        self.mesh = mesh
        if mesh is not None:
            axes, seen = [], set()
            for a in batch_axes:
                if a in mesh.axis_names and a not in seen:
                    axes.append(a)
                    seen.add(a)
            axes = tuple(axes)
            self.sharding = NamedSharding(mesh, P(axes))
        else:
            self.sharding = None

    def batch_at(self, step: int) -> dict:
        batch = self.stream.batch_at(step)
        if self.sharding is None:
            return batch
        return {k: jax.device_put(v, self.sharding) for k, v in batch.items()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
