"""Static HLO inspection: wire compression, donation aliasing, host
transfers (DESIGN.md §10).

Generalizes ``launch/roofline.wire_bytes_match`` (which pins exact byte
counts for the CI codecs) into invariants that hold for EVERY registered
codec x wire:

  hlo-uncompressed-wire   error  a compressed codec's collective-permute /
                                 all-reduce traffic is f32-heavier than the
                                 codec's own declared ``wire_bytes`` split —
                                 i.e. something decompressed the payload
                                 before the wire.  Also fires when a dtype
                                 the codec ships (s8 levels, s32 indices)
                                 is absent from the wire entirely.
  hlo-f32-allreduce-payload error a payload-sized f32 all-reduce appears in
                                 a compressed-wire program (a psum of
                                 dequantized gradients sneaking past the
                                 ring).  Metric scalars (a few bytes) pass.
  hlo-missing-donation    error  the sweep engine's donated grid carries
                                 (w, ArtemisState) are not all aliased to
                                 outputs (``tf.aliasing_output`` in lowered
                                 StableHLO / ``input_output_alias`` in the
                                 compiled module).
  hlo-host-transfer       error  infeed/outfeed/send/recv/host-callback ops
                                 in a compiled module that should be
                                 device-resident end to end.

The codec x wire matrix needs a multi-device mesh, so it runs in a child
interpreter with 8 fake CPU devices (same pattern as trace_audit's
bucket_ring entry); findings come back as JSON lines.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
from typing import Dict, List, Sequence

from repro.analysis.findings import Finding

RULES = {
    "hlo-uncompressed-wire": "error",
    "hlo-f32-allreduce-payload": "error",
    "hlo-missing-donation": "error",
    "hlo-host-transfer": "error",
    "hlo-entry-error": "error",
}

# extra f32 share of the wire we tolerate beyond the codec's declaration
# (padding, layout fragmentation); a decompressed payload jumps f32 from a
# few percent to ~50-100%, far past this
F32_SLACK = 0.15

_ALIAS_RE = re.compile(r"tf\.aliasing_output")
_HOST_RE = re.compile(
    r"\b(infeed|outfeed|send|send-done|recv|recv-done)\b"
    r"|xla_python_cpu_callback|xla_ffi_python|CustomCall.*host")


def count_output_aliases(stablehlo_text: str) -> int:
    return len(_ALIAS_RE.findall(stablehlo_text))


def host_transfer_findings(hlo_text: str, entry: str) -> List[Finding]:
    hits = sorted({m.group(0) for m in _HOST_RE.finditer(hlo_text)})
    if not hits:
        return []
    return [Finding(
        rule="hlo-host-transfer", severity="error", path=entry, line=0,
        message=f"compiled module contains host-transfer op(s) "
                f"{', '.join(hits)} — the program is expected to stay "
                f"device-resident (a debug callback or numpy round-trip "
                f"leaked into the traced region)")]


def wire_findings(measured: Dict[tuple, int], declared: Dict[str, float],
                 entry: str, *, payload_f32_bytes: float) -> List[Finding]:
    """Check measured collective bytes-per-dtype against the codec's own
    declared wire split.

    measured: roofline.collective_dtype_bytes output ({(op, dtype): bytes}).
    declared: codec ``wire_bytes`` split ({hlo_dtype: bytes}) for one
        payload — only the *fractions* are used, so hop counts and bucket
        multiplicity cancel out.
    payload_f32_bytes: size of ONE uncompressed f32 payload — the threshold
        separating metric all-reduces (bytes) from gradient-sized ones.
    """
    findings: List[Finding] = []
    cp = {dt: float(b) for (op, dt), b in measured.items()
          if op == "collective-permute"}
    total_decl = sum(declared.values())
    total_cp = sum(cp.values())
    compressed = {dt for dt, b in declared.items() if dt != "f32" and b > 0}
    if compressed and total_cp > 0 and total_decl > 0:
        frac_decl = declared.get("f32", 0.0) / total_decl
        frac_meas = cp.get("f32", 0.0) / total_cp
        if frac_meas > frac_decl + F32_SLACK:
            findings.append(Finding(
                rule="hlo-uncompressed-wire", severity="error", path=entry,
                line=0,
                message=f"f32 is {frac_meas:.0%} of collective-permute "
                        f"bytes but the codec declares {frac_decl:.0%} "
                        f"(scales/values only) — the payload crossed the "
                        f"wire decompressed"))
        for dt in sorted(compressed):
            if cp.get(dt, 0.0) <= 0:
                findings.append(Finding(
                    rule="hlo-uncompressed-wire", severity="error",
                    path=entry, line=0,
                    message=f"codec declares {dt} payload leaves but no "
                            f"{dt} collective-permute appears in HLO — the "
                            f"compressed leg of the wire is gone"))
    if compressed:
        ar_f32 = float(measured.get(("all-reduce", "f32"), 0))
        if ar_f32 >= payload_f32_bytes:
            findings.append(Finding(
                rule="hlo-f32-allreduce-payload", severity="error",
                path=entry, line=0,
                message=f"f32 all-reduce moves {ar_f32:.0f} bytes >= one "
                        f"uncompressed payload ({payload_f32_bytes:.0f}) — "
                        f"a dense psum is bypassing the compressed ring "
                        f"(metric scalars are orders of magnitude smaller)"))
    return findings


# ---------------------------------------------------------------------------
# entry: sweep donation + host transfers (single device)
# ---------------------------------------------------------------------------

def audit_sweep() -> List[Finding]:
    import jax
    from repro.core import artemis as art
    from repro.core import federated as fed
    from repro.core import sweep as sw

    n, d = 4, 8
    prob, _ = fed.make_lsr_problem(jax.random.PRNGKey(1), n_workers=n,
                                   n_per=20, d=d, noise=0.3)
    cfgs = [art.variant_config(v, d, n, p=0.7) for v in ("sgd", "artemis")]
    lowered = sw.lower_sweep(prob, cfgs, [0.01, 0.02], [0, 1], iters=8,
                             batch=2)
    findings: List[Finding] = []
    # the donated carry is (w0b, st0b): 1 + len(ArtemisState leaves) buffers
    expected = 1 + len(jax.tree.leaves(art.init_state(cfgs[0])))
    got = count_output_aliases(lowered.as_text())
    if got < expected:
        findings.append(Finding(
            rule="hlo-missing-donation", severity="error", path="sweep_grid",
            line=0,
            message=f"only {got}/{expected} donated grid-carry buffers are "
                    f"aliased to outputs (tf.aliasing_output) — the sweep "
                    f"no longer updates the carry in place"))
    compiled_text = lowered.compile().as_text()
    if "input_output_alias" not in compiled_text:
        findings.append(Finding(
            rule="hlo-missing-donation", severity="error", path="sweep_grid",
            line=0,
            message="compiled sweep module has no input_output_alias "
                    "entries — XLA dropped every donation"))
    findings.extend(host_transfer_findings(compiled_text, "sweep_grid"))
    return findings


# ---------------------------------------------------------------------------
# entry: codec x wire matrix (8-device child)
# ---------------------------------------------------------------------------

def _child_mesh_wires():
    """Child body: lower the mesh train step for every registered codec x
    wire on a 4-worker mesh; print findings as JSON lines."""
    import jax
    import numpy as np
    from repro.core import codec as wire
    from repro.core import dist
    from repro.launch import roofline
    from repro.models.toy import ToyMLP
    from repro.optim import sgd

    mesh = dist.make_worker_mesh((4,), ("pod",))
    model = ToyMLP(n_layers=2, d=32)
    params = model.init(jax.random.PRNGKey(0))
    n_elems = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    batch = model.batch(jax.random.PRNGKey(1), n=16)
    codecs = [c for c in wire.available() if c != "none"]  # identity alias
    for cname in codecs:
        for w in dist.WIRES:
            entry = f"mesh:{cname}:{w}"
            try:
                dcfg = dist.DistConfig(worker_axes=("pod",),
                                       variant="artemis", s=3, wire=w,
                                       reduce_impl="pipelined", codec=cname)
                init_state, step_fn = dist.make_train_step(
                    model, sgd(0.05), dcfg, mesh)
                state = init_state(params)
                hlo = jax.jit(step_fn).lower(state, batch).compile().as_text()
                lay = dcfg.layout(params)
                wc = dcfg.wire_codec(lay.row)
                declared = {dt: float(b) for dt, b in
                            wc.wire_bytes((lay.rows, lay.row)).items()}
                fs = wire_findings(
                    roofline.collective_dtype_bytes(hlo), declared, entry,
                    payload_f32_bytes=4.0 * n_elems)
                fs.extend(host_transfer_findings(hlo, entry))
            except Exception as e:
                fs = [Finding(rule="hlo-entry-error", severity="error",
                              path=entry, line=0,
                              message=f"lowering failed: "
                                      f"{type(e).__name__}: {e}")]
            for f in fs:
                print("HLOJSON " + json.dumps({  # repro-lint: allow=print-in-library (subprocess protocol)
                    "rule": f.rule, "severity": f.severity, "path": f.path,
                    "line": f.line, "message": f.message}))
    print("HLODONE")  # repro-lint: allow=print-in-library (subprocess protocol)


def audit_mesh_wires() -> List[Finding]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = root + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis.hlo_checks",
         "--child", "mesh_wires"],
        capture_output=True, text=True, env=env, timeout=600)
    if res.returncode != 0 or "HLODONE" not in res.stdout:
        tail = (res.stderr or res.stdout).strip().splitlines()[-12:]
        return [Finding(
            rule="hlo-entry-error", severity="error", path="mesh_wires",
            line=0,
            message="mesh wire audit child failed: " + " | ".join(tail))]
    findings = []
    for line in res.stdout.splitlines():
        if line.startswith("HLOJSON "):
            findings.append(Finding(**json.loads(line[len("HLOJSON "):])))
    return findings


def audit_all(*, mesh: bool = True) -> List[Finding]:
    findings: List[Finding] = []
    for name, fn in (("sweep", audit_sweep),
                     ("mesh_wires", audit_mesh_wires if mesh else None)):
        if fn is None:
            continue
        try:
            findings.extend(fn())
        except Exception as e:                        # pragma: no cover
            findings.append(Finding(
                rule="hlo-entry-error", severity="error", path=name, line=0,
                message=f"audit raised {type(e).__name__}: {e}"))
    return findings


if __name__ == "__main__":
    if len(sys.argv) == 3 and sys.argv[1] == "--child":
        if sys.argv[2] == "mesh_wires":
            _child_mesh_wires()
        else:
            raise SystemExit(f"unknown child entry {sys.argv[2]!r}")
    else:
        fs = audit_all()
        for f in fs:
            print(f.format())  # repro-lint: allow=print-in-library (CLI entry)
        raise SystemExit(1 if fs else 0)
