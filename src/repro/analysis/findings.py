"""Finding model + JSON/SARIF emission + baseline suppression (DESIGN.md §10).

A ``Finding`` is one rule violation at one location.  The three analyzer
layers (``astlint``, ``trace_audit``, ``hlo_checks``) all report through this
type so the CLI can merge, suppress, and serialize them uniformly.

Suppression has two mechanisms:

  * inline pragma — ``# repro-lint: allow=<rule>[,<rule>...]`` on the
    flagged line (or on the ``def`` line to cover a whole function for
    astlint rules).  For invariants that are *deliberate*, with the
    justification living next to the code.
  * baseline file — committed JSON (``analysis_baseline.json``) listing
    ``{"rule": ..., "path": ..., "reason": ...}`` entries; matches every
    finding of that rule in that file.  For grandfathered findings that
    should not fail CI but are not endorsed in-code.

Severities: ``error`` (contract violation — fails ``--ci``), ``warning``
(likely bug — fails ``--ci``), ``info`` (advisory — never fails).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Sequence

SEVERITIES = ("error", "warning", "info")

# SARIF severity mapping (SARIF has no "info"/"note" distinction we need)
_SARIF_LEVEL = {"error": "error", "warning": "warning", "info": "note"}


@dataclasses.dataclass
class Finding:
    rule: str                   # rule id, kebab-case (see astlint.RULES etc.)
    severity: str               # 'error' | 'warning' | 'info'
    path: str                   # repo-relative path ('' for repo-level rules)
    line: int                   # 1-based; 0 when not tied to a line
    message: str
    suppressed: bool = False    # set by apply_baseline / inline pragma
    suppressed_by: str = ""     # 'pragma' | 'baseline'

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def key(self) -> str:
        return f"{self.path}:{self.line}:{self.rule}"

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.path else "<repo>"
        sup = f" [suppressed:{self.suppressed_by}]" if self.suppressed else ""
        return f"{loc}: {self.severity}: {self.rule}: {self.message}{sup}"


def active(findings: Iterable[Finding]) -> List[Finding]:
    """Findings that should fail --ci: unsuppressed errors and warnings."""
    return [f for f in findings
            if not f.suppressed and f.severity in ("error", "warning")]


# ---------------------------------------------------------------------------
# baseline / suppression file
# ---------------------------------------------------------------------------

def load_baseline(path: Optional[str]) -> List[Dict]:
    if path is None:
        return []
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return []
    entries = data.get("suppressions", [])
    for e in entries:
        if "rule" not in e:
            raise ValueError(f"baseline entry missing 'rule': {e}")
    return entries


def apply_baseline(findings: Sequence[Finding],
                   entries: Sequence[Dict]) -> List[Finding]:
    """Mark findings matched by a baseline entry as suppressed (in place).

    An entry matches on ``rule`` plus, when present, ``path`` (exact
    repo-relative match) and ``line``.  Line-less entries survive edits that
    move code around; line-pinned entries are for one of several findings of
    the same rule in one file.
    """
    for f in findings:
        if f.suppressed:
            continue
        for e in entries:
            if e["rule"] != f.rule:
                continue
            if "path" in e and e["path"] != f.path:
                continue
            if "line" in e and int(e["line"]) != f.line:
                continue
            f.suppressed = True
            f.suppressed_by = "baseline"
            break
    return list(findings)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def to_json(findings: Sequence[Finding]) -> str:
    payload = {
        "version": 1,
        "findings": [dataclasses.asdict(f) for f in findings],
        "counts": {
            "total": len(findings),
            "active": len(active(findings)),
            "suppressed": sum(1 for f in findings if f.suppressed),
        },
    }
    return json.dumps(payload, indent=2, sort_keys=True)


def to_sarif(findings: Sequence[Finding], *,
             tool_name: str = "repro.analysis") -> str:
    """Minimal SARIF 2.1.0 document (one run, one result per finding)."""
    rules = sorted({f.rule for f in findings})
    results = []
    for f in findings:
        res = {
            "ruleId": f.rule,
            "level": _SARIF_LEVEL[f.severity],
            "message": {"text": f.message},
        }
        if f.path:
            res["locations"] = [{
                "physicalLocation": {
                    "artifactLocation": {"uri": f.path},
                    "region": {"startLine": max(f.line, 1)},
                },
            }]
        if f.suppressed:
            res["suppressions"] = [{
                "kind": "external" if f.suppressed_by == "baseline"
                else "inSource",
            }]
        results.append(res)
    doc = {
        "$schema": ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                    "master/Schemata/sarif-schema-2.1.0.json"),
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {
                "name": tool_name,
                "rules": [{"id": r} for r in rules],
            }},
            "results": results,
        }],
    }
    return json.dumps(doc, indent=2, sort_keys=True)
