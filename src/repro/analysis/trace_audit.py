"""Compile-count contract auditor (DESIGN.md §10).

The sweep engine's whole value proposition (PR 1: 19x cold / 327x warm) is
"one trace for the whole grid".  Nothing enforced that dynamically: a future
change that sneaks a Python-varying value into the jitted signature silently
reverts to per-cell retracing and every benchmark number rots.  This module
*executes* registered entry points under ``jax_log_compiles`` and asserts
each one compiles exactly once across a multi-cell workload.

Mechanics: with ``jax.config jax_log_compiles`` on, the ``jax._src.dispatch``
logger emits one ``Finished XLA compilation of jit(<name>) in ...`` record
per backend compilation.  We attach a capturing handler to exactly that
logger (attaching to several jax loggers double-counts via propagation) and
count records per jit name.

Entry points audited (each runs a *multi-cell* workload):

  sweep_grid          run_sweep over {2 variants}x{2 gammas}x{2 seeds} —
                      expects exactly one ``jit(sweep)`` compile, and the
                      engine's own ``trace_count`` delta == 1.
  artemis_round_dense 3 rounds of artemis_round(backend='dense') under one
  artemis_round_pallas  jit wrapper — one compile each.
  bucket_ring         the mesh backend's pipelined bucketed ring train step
                      (subprocess: needs 8 fake CPU devices via XLA_FLAGS
                      *before* jax initializes) — one ``jit(step_fn)``.

``audit_no_retrace(fn, calls, name)`` is the reusable core: tests use it to
prove the auditor *does* flag a deliberately retracing callable.
"""
from __future__ import annotations

import logging
import os
import re
import subprocess
import sys
from contextlib import contextmanager
from typing import Callable, Dict, List, Sequence, Tuple

from repro.analysis.findings import Finding

RULES = {
    "trace-retrace": "error",      # entry point compiled != expected count
    "trace-entry-error": "error",  # entry point raised while auditing
}

_COMPILE_RE = re.compile(r"Finished XLA compilation of jit\(([^)]*)\)")
# the one logger that emits exactly one record per compilation in this jax
_LOGGER = "jax._src.dispatch"


class _Capture(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.names: List[str] = []

    def emit(self, record):
        m = _COMPILE_RE.search(record.getMessage())
        if m:
            self.names.append(m.group(1))


@contextmanager
def compile_log():
    """Context manager yielding a list of jit names compiled inside it."""
    import jax
    cap = _Capture()
    logger = logging.getLogger(_LOGGER)
    # pxla logs a second "Compiling <name> ..." record per compile; jax
    # installs its OWN stream handlers on both loggers when the flag flips,
    # so muting propagation is not enough — swap the handler lists out
    # entirely for the duration (capture only; stderr stays clean)
    pxla = logging.getLogger("jax._src.interpreters.pxla")
    prev = jax.config.jax_log_compiles
    jax.config.update("jax_log_compiles", True)
    saved = [(lg, list(lg.handlers), lg.propagate, lg.level)
             for lg in (logger, pxla)]
    logger.handlers = [cap]
    logger.propagate = False
    if logger.level > logging.WARNING:
        logger.setLevel(logging.WARNING)
    # NullHandler, not [] — an empty handler list falls through to
    # logging.lastResort, which prints the bare record to stderr anyway
    pxla.handlers = [logging.NullHandler()]
    pxla.propagate = False
    try:
        yield cap.names
    finally:
        jax.config.update("jax_log_compiles", prev)
        for lg, handlers, prop, level in saved:
            lg.handlers = handlers
            lg.propagate = prop
            lg.setLevel(level)


def compile_counts(names: Sequence[str]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for n in names:
        out[n] = out.get(n, 0) + 1
    return out


def audit_no_retrace(fn: Callable, calls: Sequence[tuple], name: str,
                     *, expect: int = 1,
                     entry: str = "<anonymous>") -> List[Finding]:
    """Run ``fn(*args)`` for each args tuple; assert jit ``name`` compiled
    exactly ``expect`` times across ALL calls."""
    import jax
    with compile_log() as names:
        for args in calls:
            jax.block_until_ready(fn(*args))
    got = compile_counts(names).get(name, 0)
    if got != expect:
        return [Finding(
            rule="trace-retrace", severity="error", path=entry, line=0,
            message=f"jit({name}) compiled {got}x across {len(calls)} "
                    f"call(s), expected {expect} — the one-trace contract "
                    f"is broken (a traced-signature leak retraces per call)")]
    return []


# ---------------------------------------------------------------------------
# registered entry points
# ---------------------------------------------------------------------------

def _audit_sweep_grid() -> List[Finding]:
    import jax
    from repro.core import artemis as art
    from repro.core import federated as fed
    from repro.core import sweep as sw

    n, d = 4, 8
    prob, _ = fed.make_lsr_problem(jax.random.PRNGKey(0), n_workers=n,
                                   n_per=20, d=d, noise=0.3)
    cfgs = [art.variant_config(v, d, n, p=0.7) for v in ("sgd", "artemis")]
    t0 = sw.trace_count()
    with compile_log() as names:
        sw.run_sweep(prob, cfgs, [0.01, 0.02], [0, 1], iters=8, batch=2)
    findings = []
    got = compile_counts(names).get("sweep", 0)
    if got != 1:
        findings.append(Finding(
            rule="trace-retrace", severity="error", path="sweep_grid", line=0,
            message=f"jit(sweep) compiled {got}x for a 2x2x2 grid, expected "
                    f"exactly 1 (one-trace sweep contract, DESIGN.md §2)"))
    traces = sw.trace_count() - t0
    if traces > 1:
        findings.append(Finding(
            rule="trace-retrace", severity="error", path="sweep_grid", line=0,
            message=f"sweep engine trace counter advanced {traces}x for one "
                    f"grid (expected <=1) — per-cell retracing is back"))
    return findings


def _artemis_entry(backend: str) -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.core import artemis as art

    d, n = 16, 4
    cfg = art.variant_config("artemis", d, n, s=1, p=0.5)
    state = art.init_state(cfg)

    def artemis_round_entry(state, grads, key):
        return art.artemis_round(cfg, state, grads, key, backend=backend)

    fn = jax.jit(artemis_round_entry)
    calls = []
    key = jax.random.PRNGKey(3)
    for i in range(3):
        key, k1, k2 = jax.random.split(key, 3)
        grads = jax.random.normal(k1, (n, d))
        calls.append((state, grads, k2))
    return audit_no_retrace(fn, calls, "artemis_round_entry",
                            entry=f"artemis_round_{backend}")


def _audit_artemis_dense() -> List[Finding]:
    return _artemis_entry("dense")


def _audit_artemis_pallas() -> List[Finding]:
    return _artemis_entry("pallas")


# the bucket-ring audit must configure 8 fake CPU devices before jax loads,
# so it runs in a child interpreter (same pattern as tests/helpers mesh
# scenarios); the child prints compile counts on the last line.
_CHILD_OK_RE = re.compile(r"^AUDIT ([a-zA-Z_0-9]+)=(\d+)$", re.M)


def _audit_bucket_ring() -> List[Finding]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = root + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis.trace_audit",
         "--child", "bucket_ring"],
        capture_output=True, text=True, env=env, timeout=600)
    if res.returncode != 0:
        tail = (res.stderr or res.stdout).strip().splitlines()[-12:]
        return [Finding(
            rule="trace-entry-error", severity="error", path="bucket_ring",
            line=0,
            message="bucket_ring audit child failed: " + " | ".join(tail))]
    counts = {m.group(1): int(m.group(2))
              for m in _CHILD_OK_RE.finditer(res.stdout)}
    got = counts.get("step_fn", 0)
    if got != 1:
        return [Finding(
            rule="trace-retrace", severity="error", path="bucket_ring",
            line=0,
            message=f"jit(step_fn) compiled {got}x over 3 pipelined-ring "
                    f"rounds on the 8-device mesh, expected exactly 1")]
    return []


def _child_bucket_ring():
    """Child-process body: 3 rounds of the bucketed pipelined mesh step
    (same construction idiom as tests/helpers/bucket_scenarios._setup)."""
    import jax
    from repro.core import dist
    from repro.models.toy import ToyMLP
    from repro.optim import sgd

    mesh = dist.make_worker_mesh((2, 2), ("p", "q"))
    model = ToyMLP(n_layers=2, d=32)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = dist.DistConfig(worker_axes=("p", "q"), variant="artemis", s=3,
                           wire="bucketed", reduce_impl="pipelined")
    init_state, step_fn = dist.make_train_step(model, sgd(0.05), dcfg, mesh)
    state = init_state(params)
    jstep = jax.jit(step_fn)
    with compile_log() as names:
        for i in range(3):
            state, _ = jstep(state, model.batch(jax.random.PRNGKey(i), n=16))
        jax.block_until_ready(state)
    for name, count in sorted(compile_counts(names).items()):
        print(f"AUDIT {name}={count}")  # repro-lint: allow=print-in-library (subprocess protocol)


ENTRY_POINTS: Dict[str, Callable[[], List[Finding]]] = {
    "sweep_grid": _audit_sweep_grid,
    "artemis_round_dense": _audit_artemis_dense,
    "artemis_round_pallas": _audit_artemis_pallas,
    "bucket_ring": _audit_bucket_ring,
}


def audit_entry_points(only: Sequence[str] = ()) -> List[Finding]:
    findings: List[Finding] = []
    for name, fn in ENTRY_POINTS.items():
        if only and name not in only:
            continue
        try:
            findings.extend(fn())
        except Exception as e:                        # pragma: no cover
            findings.append(Finding(
                rule="trace-entry-error", severity="error", path=name,
                line=0, message=f"entry point raised {type(e).__name__}: {e}"))
    return findings


if __name__ == "__main__":
    if len(sys.argv) == 3 and sys.argv[1] == "--child":
        if sys.argv[2] == "bucket_ring":
            _child_bucket_ring()
        else:
            raise SystemExit(f"unknown child entry {sys.argv[2]!r}")
    else:
        fs = audit_entry_points(sys.argv[1:])
        for f in fs:
            print(f.format())  # repro-lint: allow=print-in-library (CLI entry)
        raise SystemExit(1 if fs else 0)
