"""repro.analysis — JAX/Pallas-aware lint + trace/HLO contract auditor.

Three layers (DESIGN.md §10), all reporting ``findings.Finding``:

  * ``astlint``     — stdlib-ast rules over source (PRNG discipline, tracer
                      branching, jit'd mutable globals, hard-coded
                      ``interpret=``, unhashable statics, repo hygiene).
  * ``trace_audit`` — executes registered entry points under
                      ``jax_log_compiles`` and asserts the one-compile
                      contract (sweep grid, artemis_round per backend, the
                      bucketed pipelined ring).
  * ``hlo_checks``  — static StableHLO/HLO inspection (compressed wire
                      stays compressed, donated carries alias outputs, no
                      host transfers).

CLI: ``python -m repro.analysis [--ci] [--json F] [--sarif F] ...`` — lint
only by default; ``--ci`` adds the dynamic audits and is the ci.sh gate.
"""
from repro.analysis.findings import (Finding, active, apply_baseline,
                                     load_baseline, to_json, to_sarif)

__all__ = ["Finding", "active", "apply_baseline", "load_baseline",
           "to_json", "to_sarif"]
