"""stdlib-``ast`` lint rules for JAX/Pallas discipline (DESIGN.md §10).

Rules (ids are stable — they key the baseline file and SARIF output):

  prng-key-reuse         error    the same PRNG key expression is *strongly*
                                  consumed (sampled from, or split) more than
                                  once in one function scope.  Multiple
                                  ``fold_in`` derivations off one key are the
                                  repo's idiomatic salted side streams and are
                                  NOT counted (weak consumption).
  prng-split-overflow    error    ``ks = jax.random.split(key, N)`` followed
                                  by a subscript ``ks[i]`` with ``i >= N``.
  tracer-python-branch   warning  ``if``/``while``/``assert`` test calls into
                                  ``jnp.*`` / ``jax.numpy.*`` — Python control
                                  flow on a traced value fails (or silently
                                  constant-folds) under ``jit``.
  jit-mutable-global     warning  a jit-wrapped function declares ``global``
                                  (module state mutated at trace time only)
                                  or closes over a module-level mutable
                                  literal (dict/list/set) — both are invisible
                                  to XLA after the first trace.
  hardcoded-interpret    warning  a call site passes a constant
                                  ``interpret=True/False`` instead of routing
                                  through ``kernels.default_interpret()`` —
                                  pins CPU-interpret (or Mosaic) regardless of
                                  backend/REPRO_INTERPRET.
  static-unhashable-default error a parameter named in ``static_argnames``
                                  has an unhashable (list/dict/set) default —
                                  every call through the default raises
                                  inside ``jit``.
  tracked-bytecode       error    repo hygiene: ``git ls-files`` reports
                                  committed ``.pyc``/``.pyo``/``__pycache__``
                                  entries (moved here from the old ci.sh
                                  stage-0 inline check).
  print-in-library       warning  a library module calls ``print(...)``:
                                  human output belongs in a CLI entry point
                                  (``__main__.py`` modules and
                                  ``launch/report.py`` are exempt) or routed
                                  through ``repro.obs.events.EventLog``
                                  (``echo=True`` mirrors to the console).
                                  Subprocess-protocol prints carry a pragma.

Inline suppression: ``# repro-lint: allow=<rule>[,<rule>]`` on the flagged
line or on the enclosing ``def`` line.
"""
from __future__ import annotations

import ast
import os
import re
import subprocess
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding

RULES = {
    "prng-key-reuse": "error",
    "prng-split-overflow": "error",
    "tracer-python-branch": "warning",
    "jit-mutable-global": "warning",
    "hardcoded-interpret": "warning",
    "static-unhashable-default": "error",
    "tracked-bytecode": "error",
    "print-in-library": "warning",
}

# files where bare print() IS the interface: CLI entry modules and the
# stdout-rendering report generator
_PRINT_EXEMPT_BASENAMES = frozenset({"__main__.py"})
_PRINT_EXEMPT_SUFFIXES = ("launch/report.py",)

# jax.random functions that *strongly* consume their key argument: the key
# must never reach two of these.
_STRONG_KEY_FNS = frozenset({
    "split", "normal", "uniform", "bernoulli", "randint", "permutation",
    "choice", "truncated_normal", "gamma", "exponential", "laplace",
    "categorical", "bits", "gumbel", "beta", "dirichlet", "poisson",
    "rademacher", "cauchy", "multivariate_normal", "shuffle",
})
# weak consumption: deriving a salted stream is idiomatic repo practice
# (fold_in(key, SALT) next to split(key) — see core/sweep.py micro body)
_WEAK_KEY_FNS = frozenset({"fold_in"})

_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*allow=([\w,\-]+)")

# jnp functions that inspect dtype/shape metadata, not array values — safe in
# Python control flow even under jit (they never return tracers)
_METADATA_FNS = frozenset({
    "issubdtype", "isdtype", "result_type", "promote_types", "dtype",
    "ndim", "shape", "size", "iscomplexobj", "isrealobj", "can_cast",
})


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.split' for Attribute chains, 'split' for Names."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_random_call(call: ast.Call) -> Optional[str]:
    """The jax.random function name if ``call`` is one, else None."""
    dn = _dotted(call.func)
    if dn is None:
        return None
    parts = dn.split(".")
    fn = parts[-1]
    if fn not in _STRONG_KEY_FNS and fn not in _WEAK_KEY_FNS:
        return None
    # require an explicit random namespace: jax.random.split, random.split,
    # jrandom.split ... (a bare `split(...)` is likely user code)
    if len(parts) < 2 or "random" not in parts[-2] and parts[-2] != "jr":
        return None
    return fn


def _key_expr(call: ast.Call) -> Optional[Tuple[str, object]]:
    """(base_name, subscript_index|'') of the key argument, or None if the
    key is an arbitrary expression (fresh derivation — nothing to track)."""
    if not call.args:
        return None
    arg = call.args[0]
    if isinstance(arg, ast.Name):
        return (arg.id, "")
    if (isinstance(arg, ast.Subscript) and isinstance(arg.value, ast.Name)
            and isinstance(arg.slice, ast.Constant)
            and isinstance(arg.slice.value, int)):
        return (arg.value.id, arg.slice.value)
    return None


def _fmt_key(name: str, idx) -> str:
    return f"{name}[{idx}]" if idx != "" else name


class _FunctionScope:
    """Per-function PRNG bookkeeping (generation-aware: rebinding a name
    starts a fresh key)."""

    def __init__(self):
        self.gen: Dict[str, int] = {}
        self.strong: Dict[Tuple[str, int, object], Tuple[int, str]] = {}
        self.splits: Dict[Tuple[str, int], int] = {}   # (name, gen) -> count

    def generation(self, name: str) -> int:
        return self.gen.get(name, 0)

    def bump(self, name: str):
        self.gen[name] = self.gen.get(name, 0) + 1


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.findings: List[Finding] = []
        self.jitted_names: Set[str] = set()
        self.static_names: Dict[str, Tuple[str, ...]] = {}
        self.module_mutables: Dict[str, int] = {}      # name -> lineno
        self.def_line_stack: List[int] = []

    # -- finding helper with pragma handling --------------------------------

    def emit(self, rule: str, line: int, message: str):
        f = Finding(rule=rule, severity=RULES[rule], path=self.path,
                    line=line, message=message)
        for ln in (line, *self.def_line_stack[-1:]):
            if 1 <= ln <= len(self.lines):
                m = _PRAGMA_RE.search(self.lines[ln - 1])
                if m and rule in m.group(1).split(","):
                    f.suppressed, f.suppressed_by = True, "pragma"
                    break
        self.findings.append(f)

    # -- module-level pre-pass ----------------------------------------------

    def scan_module(self, tree: ast.Module):
        """Collect jit-wrapped function names, their static_argnames, and
        module-level mutable-literal globals before the main walk."""
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                dn = _dotted(node.func)
                if dn in ("jax.jit", "jit", "pjit", "jax.pjit"):
                    # jax.jit(fn, ...) with a plain function reference
                    if node.args and isinstance(node.args[0], ast.Name):
                        self.jitted_names.add(node.args[0].id)
                        self._record_statics(node, node.args[0].id)
            if isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    names = self._jit_decorator(dec)
                    if names is not None:
                        self.jitted_names.add(node.name)
                        if names:
                            self.static_names[node.name] = names
        for stmt in tree.body:
            if isinstance(stmt, ast.Assign) and isinstance(
                    stmt.value, (ast.Dict, ast.List, ast.Set)):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        self.module_mutables[t.id] = stmt.lineno

    def _jit_decorator(self, dec: ast.AST) -> Optional[Tuple[str, ...]]:
        """static_argnames tuple if ``dec`` is a jit decorator (possibly via
        functools.partial), else None."""
        if isinstance(dec, ast.Name) and dec.id in ("jit",):
            return ()
        if isinstance(dec, ast.Attribute) and _dotted(dec) in (
                "jax.jit", "jax.pjit"):
            return ()
        if isinstance(dec, ast.Call):
            dn = _dotted(dec.func)
            if dn in ("jax.jit", "jit", "jax.pjit", "pjit"):
                return self._statics_from_call(dec)
            if dn in ("functools.partial", "partial"):
                if dec.args and _dotted(dec.args[0]) in ("jax.jit", "jit",
                                                         "jax.pjit", "pjit"):
                    return self._statics_from_call(dec)
        return None

    def _statics_from_call(self, call: ast.Call) -> Tuple[str, ...]:
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                v = kw.value
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    return (v.value,)
                if isinstance(v, (ast.Tuple, ast.List)):
                    return tuple(e.value for e in v.elts
                                 if isinstance(e, ast.Constant))
        return ()

    def _record_statics(self, call: ast.Call, fn_name: str):
        statics = self._statics_from_call(call)
        if statics:
            self.static_names[fn_name] = statics

    # -- main walk -----------------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self.def_line_stack.append(node.lineno)
        self._check_static_defaults(node)
        if node.name in self.jitted_names:
            self._check_jit_globals(node)
        self._lint_prng(node)
        self.generic_visit(node)   # recurses into nested defs
        self.def_line_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_If(self, node: ast.If):
        self._check_tracer_branch(node.test, node.lineno, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        self._check_tracer_branch(node.test, node.lineno, "while")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert):
        self._check_tracer_branch(node.test, node.lineno, "assert")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        self._check_interpret_kw(node)
        self._check_print(node)
        self.generic_visit(node)

    # -- rule: tracer-python-branch ------------------------------------------

    def _check_tracer_branch(self, test: ast.AST, line: int, kind: str):
        for sub in ast.walk(test):
            if isinstance(sub, ast.Call):
                dn = _dotted(sub.func) or ""
                head = dn.split(".")[0]
                if (head == "jnp" or dn.startswith("jax.numpy.")) and \
                        dn.split(".")[-1] not in _METADATA_FNS:
                    self.emit(
                        "tracer-python-branch", line,
                        f"Python `{kind}` on `{dn}(...)`: a traced array in "
                        f"host control flow triggers ConcretizationError "
                        f"under jit (use lax.cond/jnp.where, or hoist the "
                        f"value out of the traced region)")
                    return

    # -- rule: hardcoded-interpret -------------------------------------------

    def _check_interpret_kw(self, call: ast.Call):
        if os.path.basename(self.path) == "__init__.py" and \
                "kernels" in self.path:
            return
        for kw in call.keywords:
            if kw.arg == "interpret" and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, bool):
                self.emit(
                    "hardcoded-interpret", call.lineno,
                    f"call passes interpret={kw.value.value} as a constant; "
                    f"route through kernels.default_interpret() so "
                    f"REPRO_INTERPRET / the backend choose the mode")

    # -- rule: print-in-library ----------------------------------------------

    def _check_print(self, call: ast.Call):
        if not (isinstance(call.func, ast.Name) and call.func.id == "print"):
            return
        p = self.path.replace(os.sep, "/")
        if os.path.basename(p) in _PRINT_EXEMPT_BASENAMES or \
                p.endswith(_PRINT_EXEMPT_SUFFIXES):
            return
        self.emit(
            "print-in-library", call.lineno,
            "library module calls print(); route human output through "
            "repro.obs.events.EventLog (echo=True mirrors to the console) "
            "or move it into a __main__ CLI module")

    # -- rule: static-unhashable-default -------------------------------------

    def _check_static_defaults(self, node: ast.FunctionDef):
        statics = self.static_names.get(node.name)
        if not statics:
            return
        args = node.args
        pos = args.posonlyargs + args.args
        defaults = [None] * (len(pos) - len(args.defaults)) + list(args.defaults)
        pairs = list(zip(pos, defaults)) + list(
            zip(args.kwonlyargs, args.kw_defaults))
        for a, d in pairs:
            if a.arg in statics and isinstance(d, (ast.Dict, ast.List,
                                                   ast.Set)):
                self.emit(
                    "static-unhashable-default", node.lineno,
                    f"static_argnames parameter {a.arg!r} of {node.name!r} "
                    f"defaults to an unhashable "
                    f"{type(d).__name__.lower()} literal — any call relying "
                    f"on the default raises inside jit")

    # -- rule: jit-mutable-global --------------------------------------------

    def _check_jit_globals(self, node: ast.FunctionDef):
        for sub in ast.walk(node):
            if isinstance(sub, ast.Global):
                self.emit(
                    "jit-mutable-global", sub.lineno,
                    f"jit-wrapped {node.name!r} mutates module global(s) "
                    f"{', '.join(sub.names)}: the write happens at trace "
                    f"time only and is invisible on cached executions")
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                    and sub.id in self.module_mutables:
                self.emit(
                    "jit-mutable-global", sub.lineno,
                    f"jit-wrapped {node.name!r} reads module-level mutable "
                    f"{sub.id!r} (defined line "
                    f"{self.module_mutables[sub.id]}): its contents are "
                    f"baked in at trace time; later mutation silently "
                    f"diverges from the compiled program")

    # -- rules: prng-key-reuse / prng-split-overflow --------------------------

    def _lint_prng(self, fn: ast.FunctionDef):
        scope = _FunctionScope()
        self._prng_stmts(fn.body, scope)

    def _prng_stmts(self, stmts: Sequence[ast.stmt], scope: _FunctionScope):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue   # nested scope: linted by its own visit
            self._prng_exprs(stmt, scope)
            if isinstance(stmt, ast.Assign):
                self._prng_assign(stmt, scope)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                t = stmt.target
                if isinstance(t, ast.Name):
                    scope.bump(t.id)
            elif isinstance(stmt, ast.For):
                if isinstance(stmt.target, ast.Name):
                    scope.bump(stmt.target.id)
                self._prng_stmts(stmt.body, scope)
                self._prng_stmts(stmt.orelse, scope)
            elif isinstance(stmt, ast.While):
                self._prng_stmts(stmt.body, scope)
                self._prng_stmts(stmt.orelse, scope)
            elif isinstance(stmt, ast.If):
                # mutually exclusive branches may each consume the same key
                # exactly once — fork the consumption map, then union so code
                # *after* the If still sees both branches' consumptions
                base = dict(scope.strong)
                self._prng_stmts(stmt.body, scope)
                body_strong = scope.strong
                scope.strong = dict(base)
                self._prng_stmts(stmt.orelse, scope)
                for slot, v in body_strong.items():
                    scope.strong.setdefault(slot, v)
            elif isinstance(stmt, ast.With):
                self._prng_stmts(stmt.body, scope)
            elif isinstance(stmt, ast.Try):
                self._prng_stmts(stmt.body, scope)
                for h in stmt.handlers:
                    self._prng_stmts(h.body, scope)
                self._prng_stmts(stmt.orelse, scope)
                self._prng_stmts(stmt.finalbody, scope)

    def _prng_exprs(self, stmt: ast.stmt, scope: _FunctionScope):
        """Record key consumptions + split-overflow subscripts that appear
        directly in this statement (not in nested blocks)."""
        blocks = []
        for field in ("body", "orelse", "finalbody", "handlers"):
            blocks.extend(getattr(stmt, field, []) or [])
        nested = {id(n) for b in blocks for n in ast.walk(b)
                  if isinstance(b, ast.AST)}
        for node in ast.walk(stmt):
            if id(node) in nested:
                continue
            if isinstance(node, ast.Call):
                fn = _is_random_call(node)
                if fn in _STRONG_KEY_FNS:
                    self._consume(node, scope)
            if isinstance(node, ast.Subscript) and \
                    isinstance(node.value, ast.Name) and \
                    isinstance(node.slice, ast.Constant) and \
                    isinstance(node.slice.value, int):
                name = node.value.id
                count = scope.splits.get((name, scope.generation(name)))
                if count is not None and node.slice.value >= count:
                    self.emit(
                        "prng-split-overflow", node.lineno,
                        f"{name}[{node.slice.value}] indexes past "
                        f"jax.random.split(..., {count}) — out of range")

    def _consume(self, call: ast.Call, scope: _FunctionScope):
        ke = _key_expr(call)
        if ke is None:
            return
        name, idx = ke
        slot = (name, scope.generation(name), idx)
        prev = scope.strong.get(slot)
        if prev is not None:
            prev_line, prev_fn = prev
            self.emit(
                "prng-key-reuse", call.lineno,
                f"PRNG key {_fmt_key(name, idx)} already consumed by "
                f"jax.random.{prev_fn} at line {prev_line}; sampling from "
                f"it again correlates the two streams (split or fold_in a "
                f"fresh key instead)")
        else:
            fn = _is_random_call(call)
            scope.strong[slot] = (call.lineno, fn)

    def _prng_assign(self, stmt: ast.Assign, scope: _FunctionScope):
        # record split counts BEFORE bumping target generations: the count
        # belongs to the freshly bound name
        split_count = None
        v = stmt.value
        if isinstance(v, ast.Call) and _is_random_call(v) == "split":
            if len(v.args) >= 2 and isinstance(v.args[1], ast.Constant) \
                    and isinstance(v.args[1].value, int):
                split_count = v.args[1].value
            for kw in v.keywords:
                if kw.arg == "num" and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, int):
                    split_count = kw.value.value
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                scope.bump(t.id)
                if split_count is not None:
                    scope.splits[(t.id, scope.generation(t.id))] = split_count
            elif isinstance(t, (ast.Tuple, ast.List)):
                for e in t.elts:
                    if isinstance(e, ast.Name):
                        scope.bump(e.id)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def lint_source(path: str, source: str) -> List[Finding]:
    """Lint one file's source text (path is used for reporting only)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="tracer-python-branch", severity="error",
                        path=path, line=e.lineno or 0,
                        message=f"syntax error: {e.msg}")]
    linter = _Linter(path, source)
    linter.scan_module(tree)
    linter.visit(tree)
    return linter.findings


def lint_file(path: str, *, rel_to: Optional[str] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    rel = os.path.relpath(path, rel_to) if rel_to else path
    return lint_source(rel, src)


def lint_paths(paths: Sequence[str], *,
               rel_to: Optional[str] = None) -> List[Finding]:
    """Lint every .py file under each path (file or directory)."""
    out: List[Finding] = []
    for p in paths:
        if os.path.isfile(p):
            out.extend(lint_file(p, rel_to=rel_to))
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = [d for d in dirs if d != "__pycache__"]
            for f in sorted(files):
                if f.endswith(".py"):
                    out.extend(lint_file(os.path.join(root, f),
                                         rel_to=rel_to))
    return out


def hygiene_findings(repo_root: str) -> List[Finding]:
    """tracked-bytecode: committed .pyc/.pyo/__pycache__ entries (the old
    ci.sh stage-0 inline check, now a first-class rule)."""
    try:
        res = subprocess.run(
            ["git", "ls-files", "*.pyc", "*.pyo", "**/__pycache__/*"],
            cwd=repo_root, capture_output=True, text=True, check=True)
    except (OSError, subprocess.CalledProcessError):
        return []          # not a git checkout — nothing to check
    tracked = [ln for ln in res.stdout.splitlines() if ln.strip()]
    return [Finding(rule="tracked-bytecode", severity="error", path=p, line=0,
                    message="bytecode file is tracked by git; "
                            "`git rm --cached` it (see .gitignore)")
            for p in tracked]
