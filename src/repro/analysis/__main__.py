"""CLI driver: ``python -m repro.analysis``.

Exit status: 0 when no *active* finding remains (errors and warnings count;
info and suppressed findings don't), 1 otherwise — so ``--ci`` is a direct
shell gate.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import findings as F
from repro.analysis import astlint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas lint + trace/HLO contract audits")
    ap.add_argument("--paths", nargs="*", default=["src"],
                    help="files/dirs to lint (default: src)")
    ap.add_argument("--ci", action="store_true",
                    help="full gate: lint + hygiene + trace audit + HLO "
                         "checks (what ci.sh runs)")
    ap.add_argument("--trace", action="store_true",
                    help="run the compile-count trace audit")
    ap.add_argument("--hlo", action="store_true",
                    help="run the static HLO checks")
    ap.add_argument("--no-mesh", action="store_true",
                    help="skip the multi-device subprocess audits (faster; "
                         "for laptops without the 570s budget)")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="suppression file (default: analysis_baseline.json;"
                         " missing file = no suppressions)")
    ap.add_argument("--json", metavar="FILE",
                    help="write findings as JSON (- for stdout)")
    ap.add_argument("--sarif", metavar="FILE",
                    help="write findings as SARIF 2.1.0 (- for stdout)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the per-finding text report")
    args = ap.parse_args(argv)

    fs = astlint.lint_paths(args.paths, rel_to=".")
    fs.extend(astlint.hygiene_findings("."))

    if args.ci or args.trace:
        from repro.analysis import trace_audit
        only = () if not args.no_mesh else tuple(
            e for e in trace_audit.ENTRY_POINTS if e != "bucket_ring")
        fs.extend(trace_audit.audit_entry_points(only))
    if args.ci or args.hlo:
        from repro.analysis import hlo_checks
        fs.extend(hlo_checks.audit_all(mesh=not args.no_mesh))

    F.apply_baseline(fs, F.load_baseline(args.baseline))
    act = F.active(fs)

    if not args.quiet:
        for f in fs:
            print(f.format())
        n_sup = sum(1 for f in fs if f.suppressed)
        print(f"repro.analysis: {len(act)} active finding(s), "
              f"{n_sup} suppressed, {len(fs)} total")
    for path, render in ((args.json, F.to_json), (args.sarif, F.to_sarif)):
        if not path:
            continue
        text = render(fs)
        if path == "-":
            print(text)
        else:
            with open(path, "w") as fh:
                fh.write(text + "\n")
    return 1 if act else 0


if __name__ == "__main__":
    sys.exit(main())
