"""falcon-mamba-7b [arXiv:2410.05355] — attention-free Mamba-1 SSM.

64 layers, d_model=4096, ssm_state=16, vocab=65024. Sub-quadratic: runs
long_500k decode with O(1) recurrent state.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv=0, d_ff=0, vocab=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    source="arXiv:2410.05355",
)

REDUCED = dataclasses.replace(
    CONFIG, name="falcon-mamba-reduced", n_layers=2, d_model=256, vocab=512,
    scan_chunk=32, q_chunk=64, xent_chunk=64, remat=False)
