"""mixtral-8x22b [arXiv:2401.04088] — 8-expert top-2 MoE with sliding-window
attention.

56 layers, d_model=6144, 48 heads (kv=8), d_ff=16384/expert, vocab=32768,
SWA window 4096.  Sub-quadratic decode via O(window) ring-buffer KV cache.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    activation="silu", n_experts=8, top_k=2,
    attn_kind="sliding", window=4096,
    source="arXiv:2401.04088",
)

REDUCED = dataclasses.replace(
    CONFIG, name="mixtral-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv=2, d_ff=256, vocab=512, n_experts=4, top_k=2, moe_group=64,
    window=64, q_chunk=64, xent_chunk=64, remat=False)
