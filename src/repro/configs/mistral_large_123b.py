"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407] — dense 123B.

88 layers, d_model=12288, 96 heads (kv=8), d_ff=28672, vocab=32768.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv=8, d_ff=28672, vocab=32768,
    activation="silu",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

REDUCED = dataclasses.replace(
    CONFIG, name="mistral-large-reduced", n_layers=2, d_model=256, n_heads=8,
    n_kv=2, d_ff=512, vocab=512, q_chunk=64, xent_chunk=64, remat=False)
