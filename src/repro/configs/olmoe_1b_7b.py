"""olmoe-1b-7b [arXiv:2409.02060] — 64-expert top-8 MoE, 1B active / 7B total.

16 layers, d_model=2048, 16 heads (kv=16), d_ff=1024 per expert, vocab=50304.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=1024, vocab=50304,
    activation="silu", n_experts=64, top_k=8,
    source="arXiv:2409.02060",
)

REDUCED = dataclasses.replace(
    CONFIG, name="olmoe-reduced", n_layers=2, d_model=128, n_heads=4, n_kv=4,
    d_ff=128, vocab=512, n_experts=4, top_k=2, moe_group=64,
    q_chunk=64, xent_chunk=64, remat=False)
