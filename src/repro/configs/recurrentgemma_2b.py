"""recurrentgemma-2b [arXiv:2402.19427] — Griffin: RG-LRU + local attention.

26 layers in a (rg, rg, local-attn) repeating pattern, d_model=2560,
10 heads (MQA kv=1), d_ff=7680, local window 2048.  Sub-quadratic decode:
O(1) recurrent states + O(window) local KV cache.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, d_ff=7680, vocab=256000,
    activation="silu", pattern=("rg", "rg", "la"), lru_width=2560,
    local_window=2048,
    source="arXiv:2402.19427",
)

REDUCED = dataclasses.replace(
    CONFIG, name="recurrentgemma-reduced", n_layers=3, d_model=256,
    n_heads=4, n_kv=1, d_ff=512, vocab=512, lru_width=256, local_window=64,
    scan_chunk=32, q_chunk=64, xent_chunk=64, remat=False)
