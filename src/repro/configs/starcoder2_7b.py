"""starcoder2-7b [arXiv:2402.19173] — dense code model, GQA kv=4, RoPE.

32 layers, d_model=4608, 36 heads (kv=4), d_ff=18432, vocab=49152, GELU MLP.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv=4, d_ff=18432, vocab=49152,
    activation="gelu",
    source="arXiv:2402.19173",
)

REDUCED = dataclasses.replace(
    CONFIG, name="starcoder2-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv=2, d_ff=512, vocab=512, q_chunk=64, xent_chunk=64, remat=False)
