"""whisper-tiny [arXiv:2212.04356] — encoder-decoder ASR transformer.

4 decoder + 4 encoder layers, d_model=384, 6 heads (kv=6), d_ff=1536,
vocab=51865.  The mel-spectrogram + conv feature extractor is STUBBED:
``input_specs`` provides precomputed frame embeddings [B, 1500, 384].
Deviation: RoPE replaces sinusoidal absolute positions (TPU-idiomatic stack);
documented in DESIGN.md.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, d_ff=1536, vocab=51865,
    activation="gelu", enc_layers=4, n_frames=1500,
    source="arXiv:2212.04356",
)

REDUCED = dataclasses.replace(
    CONFIG, name="whisper-tiny-reduced", n_layers=2, enc_layers=2,
    d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512, n_frames=8,
    q_chunk=64, xent_chunk=64, remat=False)
