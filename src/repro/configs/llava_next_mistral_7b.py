"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf] — VLM.

Mistral-7B language backbone: 32L, d_model=4096, 32H (kv=8), d_ff=14336,
vocab=32000.  The SigLIP/CLIP vision tower is STUBBED: ``input_specs``
supplies anyres patch embeddings [B, 2560, 4096] (base tile + 4 anyres tiles
x 512 tokens), consumed through a learned projector.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=32000,
    activation="silu", n_patches=2560,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

REDUCED = dataclasses.replace(
    CONFIG, name="llava-reduced", n_layers=2, d_model=256, n_heads=4, n_kv=2,
    d_ff=512, vocab=512, n_patches=16, q_chunk=64, xent_chunk=64, remat=False)
