"""nemotron-4-15b [arXiv:2402.16819] — dense, GQA, squared-ReLU.

32 layers, d_model=6144, 48 heads (kv=8), d_ff=24576, vocab=256000.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv=8, d_ff=24576, vocab=256000,
    activation="relu2",
    source="arXiv:2402.16819",
)

REDUCED = dataclasses.replace(
    CONFIG, name="nemotron-reduced", n_layers=2, d_model=256, n_heads=8,
    n_kv=2, d_ff=512, vocab=512, q_chunk=64, xent_chunk=64, remat=False)
