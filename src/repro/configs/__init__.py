"""Architecture registry + the 4 assigned input shapes + input_specs().

``input_specs`` builds ShapeDtypeStruct stand-ins for every model input --
weak-type-correct, shardable, zero allocation -- used by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCHS = {
    "whisper-tiny": "whisper_tiny",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "minitron-8b": "minitron_8b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mistral-large-123b": "mistral_large_123b",
    "starcoder2-7b": "starcoder2_7b",
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise ValueError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


def applicable(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """Returns None if (arch, shape) should run, else a skip reason."""
    if cfg.family == "encdec" and shape.name == "long_500k":
        return "encoder-decoder ASR family: 500k-token decode is meaningless"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention architecture: long_500k requires sub-quadratic "
                "decode (skip noted in DESIGN.md)")
    return None


def input_specs(cfg: ModelConfig, shape: InputShape, model=None) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's data arguments."""
    i32 = jnp.int32
    f32 = jnp.float32
    b, s = shape.batch, shape.seq
    sd = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            return {"tokens": sd((b, s - cfg.n_patches), i32),
                    "embeds": sd((b, cfg.n_patches, cfg.d_model), f32)}
        if cfg.family == "encdec":
            return {"tokens": sd((b, s), i32),
                    "frames": sd((b, cfg.n_frames, cfg.d_model), f32)}
        return {"tokens": sd((b, s), i32)}

    # decode: one token against a seq-long cache
    from repro.models.model import build_model
    mdl = model or build_model(cfg)
    cache = jax.eval_shape(lambda: mdl.init_cache(b, s))
    specs = {"token": sd((b,), i32), "pos": sd((), i32), "cache": cache}
    if cfg.family == "encdec":
        specs["enc_out"] = sd((b, cfg.n_frames, cfg.d_model),
                              jnp.dtype(cfg.compute_dtype))
    return specs
