"""minitron-8b [arXiv:2407.14679] — width-pruned Nemotron-4.

32 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=16384, vocab=256000,
squared-ReLU MLP (inherited from Nemotron-4).
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=16384, vocab=256000,
    activation="relu2",
    source="arXiv:2407.14679",
)

REDUCED = dataclasses.replace(
    CONFIG, name="minitron-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv=2, d_ff=512, vocab=512, q_chunk=64, xent_chunk=64, remat=False)
