"""Render the §Roofline markdown table from dryrun_results.json.

  PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""
from __future__ import annotations

import json
import sys

from repro.launch.roofline import markdown_table


def main(path: str):
    rows = json.load(open(path))
    ok = [r for r in rows if r.get("status") == "ok"]
    skip = [r for r in rows if r.get("status") == "skip"]
    err = [r for r in rows if r.get("status") == "error"]

    # baseline table: single-pod, dist=none
    base = [r for r in ok if r["mesh"] == "pod" and r["dist"] == "none"]
    print("### Baseline roofline — single-pod (16x16 = 256 chips)\n")
    print(markdown_table(sorted(base, key=lambda r: (r["arch"], r["shape"]))))
    print("\n### Multi-pod (2x16x16 = 512 chips) — pod axis proof + Artemis\n")
    multi = [r for r in ok if r["mesh"] == "multipod"]
    print(markdown_table(sorted(multi, key=lambda r: (r["arch"], r["shape"],
                                                      r["dist"]))))
    print("\n### Skips\n")
    for r in skip:
        if r["mesh"] == "pod":
            print(f"* {r['arch']} x {r['shape']}: {r['reason']}")
    if err:
        print("\n### ERRORS\n")
        for r in err:
            print(f"* {r['arch']} x {r['shape']} x {r['mesh']} x {r['dist']}")
    # peak memory check — peak_bytes is None on backends whose compiled
    # memory_analysis is unavailable (CPU dry-runs): render a dash, not a crash
    print("\n### Peak bytes/device (fits 16 GiB v5e?)\n")
    worst = sorted(ok, key=lambda r: -(r["memory_analysis"]["peak_bytes"] or 0))[:8]
    for r in worst:
        peak = r["memory_analysis"]["peak_bytes"]
        tag = f"{r['arch']} x {r['shape']} x {r['mesh']} x {r['dist']}"
        if peak is None:
            print(f"* {tag}: — (memory analysis unavailable)")
            continue
        pk = peak / 2**30
        print(f"* {tag}: {pk:.2f} GiB {'OK' if pk < 16 else 'OVER'}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
