import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on the production mesh with ShapeDtypeStruct stand-ins (no
allocation), print memory/cost analysis, and record roofline terms.

MUST be run as its own process (the device-count flag above is consumed at
first jax init).  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-7b \
      --shape train_4k --mesh pod [--dist artemis] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import dist
from repro.launch import mesh as M
from repro.launch import roofline as R
from repro.models.model import build_model
from repro.optim import sgd


def _param_structs(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def _parse_overrides(items):
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v == "None":
            v = None
        out[k] = v
    return out


def lower_one(arch: str, shape_name: str, mesh_kind: str, dist_variant: str,
              verbose: bool = True, cfg_overrides: dict = None,
              dist_overrides: dict = None):
    import dataclasses
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = configs.SHAPES[shape_name]
    skip = configs.applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "dist": dist_variant, "status": "skip", "reason": skip}

    mesh = M.make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    model = build_model(cfg)
    if cfg.family == "moe":
        from repro.models import moe as moe_mod
        moe_mod.set_moe_sharding(True)
    params = _param_structs(model)
    pshard = M.params_shardings(mesh, params)
    t0 = time.time()

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            dcfg = None
            if dist_variant != "none":
                waxes = ("pod",) if "pod" in mesh.axis_names else ("data",)
                dcfg = dist.DistConfig(worker_axes=waxes, variant=dist_variant,
                                       **(dist_overrides or {}))
            banned = dcfg.worker_axes if dcfg else ()
            model.set_sharding(
                None if os.environ.get("REPRO_NO_LAYER_CONSTRAINT")
                else M.layer_constraint_fn(mesh, banned),
                None if os.environ.get("REPRO_NO_ACT_CONSTRAINT")
                else M.act_constraint_fn(mesh, banned))
            opt = sgd(1e-2)
            gspecs = jax.tree.map(
                lambda ns: M.strip_axes(ns.spec, banned), pshard) if dcfg else None
            init_state, step_fn = dist.make_train_step(model, opt, dcfg, mesh,
                                                       grad_specs=gspecs)
            state = jax.eval_shape(init_state, params)
            sshard = dist.state_shardings(mesh, state, pshard, dcfg)
            batch = configs.input_specs(cfg, shape, model)
            bshard = M.batch_shardings(mesh, batch)
            fn = jax.jit(step_fn, in_shardings=(sshard, bshard))
            lowered = fn.lower(state, batch)
        elif shape.kind == "prefill":
            model.set_sharding(M.layer_constraint_fn(mesh),
                               M.act_constraint_fn(mesh))
            batch = configs.input_specs(cfg, shape, model)
            bshard = M.batch_shardings(mesh, batch)
            fn = jax.jit(model.prefill_logits, in_shardings=(pshard, bshard))
            lowered = fn.lower(params, batch)
        else:  # decode
            model.set_sharding(M.layer_constraint_fn(mesh),
                               M.act_constraint_fn(mesh))
            specs = configs.input_specs(cfg, shape, model)
            cshard = M.cache_shardings(mesh, specs["cache"])
            tshard = M.batch_shardings(mesh, {"t": specs["token"]})["t"]
            args = [params, specs["cache"], specs["token"], specs["pos"]]
            shards = [pshard, cshard, tshard, NamedSharding(mesh, P())]
            if cfg.family == "encdec":
                def serve(p, c, t, pos, enc):
                    return model.decode_step(p, c, t, pos, enc_out=enc)
                args.append(specs["enc_out"])
                shards.append(M.batch_shardings(mesh, {"e": specs["enc_out"]})["e"])
            else:
                def serve(p, c, t, pos):
                    return model.decode_step(p, c, t, pos)
            fn = jax.jit(serve, in_shardings=tuple(shards))
            lowered = fn.lower(*args)

        compiled = lowered.compile()

    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = R.collective_bytes(hlo)
    chips = mesh.devices.size
    rl = R.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        kind=shape.kind,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=coll,
        model_flops=R.model_flops(cfg, params, shape.kind, shape.batch,
                                  shape.seq) / chips,
    ).finalize()

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "dist": dist_variant, "status": "ok",
        "compile_s": round(t1 - t0, 1),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        **rl.as_dict(),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_kind} x {dist_variant}] "  # repro-lint: allow=print-in-library (CLI driver)
              f"compile={rec['compile_s']}s flops/dev={rl.hlo_flops:.3e} "
              f"bytes/dev={rl.hlo_bytes:.3e} "
              f"coll={sum(coll.values()):.3e}B dominant={rl.dominant}")
        print("  memory_analysis:", rec["memory_analysis"])  # repro-lint: allow=print-in-library (CLI driver)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--dist", default="none",
                    help="none|sgd|qsgd|diana|biqsgd|artemis (train shapes)")
    ap.add_argument("--all", action="store_true",
                    help="full matrix: every arch x shape; baseline on pod mesh "
                         "+ artemis multipod for train shapes")
    ap.add_argument("--cfg-override", action="append", default=[],
                    help="ModelConfig field override, e.g. remat_policy=dots_saveable")
    ap.add_argument("--dist-override", action="append", default=[],
                    help="DistConfig field override, e.g. memory_dtype=bfloat16")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = []
    if args.all:
        # XLA SPMD partitioner bugs abort the process (CHECK failures), so
        # each combo runs in its own subprocess.
        import subprocess
        import sys
        import tempfile
        combos = []
        for arch in configs.ARCHS:
            for shape in configs.SHAPES:
                for mesh_kind in ("pod", "multipod"):
                    dists = ["none"]
                    if (configs.SHAPES[shape].kind == "train"
                            and mesh_kind == "multipod"):
                        dists.append("artemis")
                    for dv in dists:
                        combos.append((arch, shape, mesh_kind, dv))
        for arch, shape, mesh_kind, dv in combos:
            with tempfile.NamedTemporaryFile(suffix=".json") as tf:
                proc = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                     "--dist", dv, "--out", tf.name],
                    capture_output=True, text=True, timeout=1800)
                try:
                    with open(tf.name) as f:
                        rec = json.load(f)[0]
                except Exception:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "dist": dv, "status": "error",
                           "error": (proc.stderr or proc.stdout)[-800:]}
                results.append(rec)
                print(f"{arch} x {shape} x {mesh_kind} x {dv}: {rec['status']}"  # repro-lint: allow=print-in-library (CLI driver)
                      + (f" ({rec.get('dominant','')})"
                         if rec["status"] == "ok" else ""),
                      flush=True)
    else:
        meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
        for mk in meshes:
            results.append(lower_one(
                args.arch, args.shape, mk, args.dist,
                cfg_overrides=_parse_overrides(args.cfg_override),
                dist_overrides=_parse_overrides(args.dist_override)))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print("wrote", args.out)  # repro-lint: allow=print-in-library (CLI driver)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"dryrun: {n_ok} ok, {n_skip} skip, {n_err} error")  # repro-lint: allow=print-in-library (CLI driver)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
