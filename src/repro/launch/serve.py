"""Serving driver: batched greedy decoding against a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import mesh as M
from repro.models.model import build_model


def generate(model, params, prompt, max_len, gen, enc_out=None):
    """Greedy generation: prompt [B, P] -> tokens [B, P+gen]."""
    b, plen = prompt.shape
    cache = model.init_cache(b, max_len)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(
        p, c, t, pos, enc_out=enc_out))
    toks = [prompt[:, i] for i in range(plen)]
    logits = None
    for i in range(plen):                      # prefill via decode steps
        logits, cache = step(params, cache, toks[i], jnp.int32(i))
    for i in range(gen):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(nxt)
        logits, cache = step(params, cache, nxt, jnp.int32(plen + i))
    return jnp.stack(toks, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    enc_out = None
    if cfg.family == "encdec":
        enc_out = jax.random.normal(
            jax.random.PRNGKey(3),
            (args.batch, cfg.n_frames, cfg.d_model)).astype(cfg.cdtype)

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(model, params, prompt, args.prompt_len + args.gen,
                   args.gen, enc_out=enc_out)
    dt = time.time() - t0
    toks = args.batch * (args.prompt_len + args.gen)
    print(f"generated {out.shape} in {dt:.2f}s ({toks / dt:.0f} tok/s)")  # repro-lint: allow=print-in-library (CLI driver)
    assert np.isfinite(np.asarray(out)).all()
    print("sample:", np.asarray(out[0, :16]))  # repro-lint: allow=print-in-library (CLI driver)
    return out


if __name__ == "__main__":
    main()
