"""Training driver.

Runs real steps on whatever devices exist (CPU host mesh for local runs; the
production mesh on a real cluster).  Supports every Artemis variant over a
configurable worker axis, checkpointing, and loss logging.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-7b --reduced \
      --steps 100 --batch 8 --seq 128 --dist artemis --workers data
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import checkpointer
from repro.core import dist
from repro.core import faults
from repro.data.pipeline import ShardedBatches
from repro.data.synthetic import TokenStream, TokenStreamConfig
from repro.launch import mesh as M
from repro.models.model import build_model
from repro.obs import events as obs_events
from repro.obs import spans as obs_spans
from repro.optim import adam, sgd


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adam", choices=["sgd", "adam"])
    ap.add_argument("--dist", default="none",
                    choices=["none"] + list(dist.VARIANTS))
    ap.add_argument("--workers", default="data", help="worker axis name")
    ap.add_argument("--s", type=int, default=1, help="quantization levels")
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--local-steps", type=int, default=1,
                    help="communicate every k steps (grad accumulation)")
    ap.add_argument("--mesh", default=None,
                    help="mesh shape, e.g. 4x2 => data=4, model=2")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restart from ckpt-dir/LATEST if present (without "
                         "this flag an existing checkpoint is ignored)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--log-file", default=None)
    ap.add_argument("--events", default=None,
                    help="repro.obs JSONL event log (omit: echo-only)")
    # --- fault injection + self-healing (core/faults.py, DESIGN.md §8) ---
    ap.add_argument("--straggler-rate", type=float, default=0.0)
    ap.add_argument("--p-stay", type=float, default=None,
                    help="Markov P(active->active); default i.i.d.")
    ap.add_argument("--bitflip-rate", type=float, default=0.0)
    ap.add_argument("--blowup-rate", type=float, default=0.0)
    ap.add_argument("--blowup-value", type=float, default=float("nan"))
    ap.add_argument("--scrub", action="store_true",
                    help="server-side finite/checksum payload scrubbing")
    ap.add_argument("--sentinel", type=float, default=0.0,
                    help="loss threshold: blown-up loss rolls back to the "
                         "last checkpoint with lr backoff (0 = off)")
    ap.add_argument("--backoff", type=float, default=0.5)
    ap.add_argument("--max-rollbacks", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = M.make_host_mesh()

    fc = faults.FaultConfig(
        straggler_rate=args.straggler_rate, p_stay=args.p_stay,
        bitflip_rate=args.bitflip_rate, blowup_rate=args.blowup_rate,
        blowup_value=args.blowup_value, scrub=args.scrub,
        sentinel=args.sentinel, backoff=args.backoff)
    dcfg = None
    if args.dist != "none":
        dcfg = dist.DistConfig(worker_axes=(args.workers,), variant=args.dist,
                               s=args.s, p_participation=args.participation,
                               local_steps=args.local_steps,
                               faults=fc if fc.enabled else None)

    opt = adam(args.lr) if args.optimizer == "adam" else sgd(args.lr)
    params = model.init(jax.random.PRNGKey(0))
    pshard = M.params_shardings(mesh, params)
    banned = dcfg.worker_axes if dcfg else ()
    model.set_sharding(M.layer_constraint_fn(mesh, banned),
                       M.act_constraint_fn(mesh, banned))
    gspecs = (jax.tree.map(lambda ns: M.strip_axes(ns.spec, banned), pshard)
              if dcfg else None)
    init_state, step_fn = dist.make_train_step(model, opt, dcfg, mesh,
                                               grad_specs=gspecs)
    local_fn = (dist.make_local_step(model, dcfg, mesh)
                if dcfg and dcfg.local_steps > 1 else None)

    stream = TokenStream(TokenStreamConfig(
        vocab=cfg.vocab, seq_len=args.seq, batch=args.batch))
    batches = ShardedBatches(stream, mesh, batch_axes=(args.workers, "data"))

    # console output + optional JSONL log share one schema-checked sink
    log = obs_events.EventLog(args.events, echo=True)
    log.start(config={"arch": args.arch, "dist": args.dist,
                      "steps": args.steps, "batch": args.batch,
                      "seq": args.seq, "lr": args.lr},
              fingerprint=f"{args.arch}:{args.dist}:s{args.s}")
    with jax.set_mesh(mesh):
        params = jax.device_put(params, pshard)
        state = init_state(params)
        jstep = jax.jit(step_fn)
        if (args.resume and args.ckpt_dir
                and checkpointer.latest_step(args.ckpt_dir) is not None):
            # one restore, one (re)trace: the killed run's state slots into
            # the same jitted step, so resuming compiles exactly once
            state = checkpointer.restore(args.ckpt_dir, state)
            log.emit("note", text=f"restored step {int(state.step)}")

        logs = []
        obs_spans.reset()
        t0 = time.perf_counter()
        compile_s = None        # first jstep call = compile + one step
        jlocal = jax.jit(local_fn) if local_fn else None
        # host-side divergence sentinel: last good state + geometric lr backoff
        good_state, lr_scale, rollbacks = state, 1.0, 0
        start = int(state.step)
        i = start
        while i < start + args.steps:
            batch = batches.batch_at(i)
            if jlocal is not None and (i + 1) % args.local_steps:
                state, (loss, metrics) = jlocal(state, batch)
            elif compile_s is None:
                # compile-vs-execute split: the first communicating step
                # pays the trace+compile; block so the span measures it
                with obs_spans.span("train/compile+first_step"):
                    state, (loss, metrics) = jstep(state, batch)
                    jax.block_until_ready(loss)
                compile_s = time.perf_counter() - t0
            else:
                with obs_spans.span("train/step"):
                    state, (loss, metrics) = jstep(state, batch)
            if i % args.log_every == 0 or i == start + args.steps - 1:
                loss_f = float(loss)
                bad = not np.isfinite(loss_f) or (
                    args.sentinel > 0 and loss_f > args.sentinel)
                if bad and args.sentinel > 0:
                    rollbacks += 1
                    if rollbacks > args.max_rollbacks:
                        raise RuntimeError(
                            f"loss diverged {rollbacks} times; giving up")
                    lr_scale *= args.backoff
                    state = good_state
                    opt2 = (adam(args.lr * lr_scale)
                            if args.optimizer == "adam"
                            else sgd(args.lr * lr_scale))
                    _, step_fn2 = dist.make_train_step(model, opt2, dcfg,
                                                       mesh, grad_specs=gspecs)
                    jstep = jax.jit(step_fn2)
                    log.emit("rollback", step=int(state.step),
                             count=rollbacks, lr_scale=lr_scale)
                    i = int(state.step)
                    continue
                rec = {"step": int(state.step), "loss": round(loss_f, 4),
                       "nll": round(float(metrics["nll"]), 4),
                       "wall_s": round(time.perf_counter() - t0, 1),
                       "rollbacks": rollbacks}
                logs.append(rec)
                log.emit("train_step", **rec)
                assert np.isfinite(loss_f), "loss diverged"
                good_state = state
            if (args.ckpt_every and args.ckpt_dir
                    and int(state.step) % args.ckpt_every == 0):
                checkpointer.save(args.ckpt_dir, int(state.step), state)
            i += 1
        if args.ckpt_dir:
            checkpointer.save(args.ckpt_dir, int(state.step), state)
    wall = time.perf_counter() - t0
    steady = obs_spans.total("train/step")
    if compile_s is not None:
        log.emit("span", name="train/compile+first_step", dur_s=compile_s)
    if steady > 0:
        log.emit("span", name="train/steady_steps", dur_s=steady)
    log.end(status="ok", wall_s=round(wall, 3))
    log.close()
    if args.log_file:
        with open(args.log_file, "w") as f:
            json.dump(logs, f, indent=1)
    return logs


if __name__ == "__main__":
    main()
