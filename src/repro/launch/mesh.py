"""Production mesh + sharding policy.

Mesh axes:
  multi-pod  : (pod=2, data=16, model=16) — 512 chips; 'pod' is the Artemis
               worker axis (slow DCN inter-pod links = the paper's
               bandwidth-constrained uplink/downlink).
  single-pod : (data=16, model=16) — 256 chips; Artemis (when enabled) uses
               'data' as the worker axis.

Parameter policy: 2-D sharding — reduction/feature dims over ('data',
'model') for all big matrices (FSDP x tensor), experts over 'model'
(expert parallelism), vocab over 'data'. Dims that don't divide the axis
size are left unsharded (GSPMD would pad; we prefer explicit replication).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape: Tuple[int, ...] = None, axes=None) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    if shape is None:
        shape = (n, 1)
        axes = ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _maybe(mesh: Mesh, axis: Optional[str], dim: int) -> Optional[str]:
    """Use ``axis`` only if it exists and divides ``dim``."""
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def param_spec(mesh: Mesh, path: str, shape: Tuple[int, ...],
               data_axis: str = "data", model_axis: str = "model") -> P:
    """Sharding spec for one parameter leaf (path is '/'-joined tree path)."""
    nd = len(shape)
    if "moe" in path and nd >= 3:
        # [ (L,) E, d_in, d_out ]: experts over model when E divides it
        # (expert parallelism), else fall back to 2-D (d_in x d_out) sharding
        # — e.g. mixtral's E=8 on a 16-way model axis would otherwise leave
        # 540 GB of expert weights only 16-way sharded (33 GB/chip, OOM).
        spec = [None] * nd
        out_proj = path.endswith("w_down")
        if _maybe(mesh, model_axis, shape[-3]):
            spec[-3] = model_axis
            spec[-2] = _maybe(mesh, data_axis, shape[-2])
        elif out_proj:   # contract wide dim over model (see below)
            spec[-2] = _maybe(mesh, model_axis, shape[-2])
            spec[-1] = _maybe(mesh, data_axis, shape[-1])
        else:
            spec[-2] = _maybe(mesh, data_axis, shape[-2])
            spec[-1] = _maybe(mesh, model_axis, shape[-1])
        return P(*spec)
    if path.endswith("embed") and nd == 2:       # [V, d]
        # vocab dim deliberately NOT sharded: XLA's gather partitioning on a
        # vocab-sharded table crashes under partial-manual shard_map (see
        # DESIGN.md); feature dim over model is the pass-through case.
        return P(None, _maybe(mesh, model_axis, shape[1]))
    if nd >= 2 and shape[-1] >= 128 and shape[-2] >= 128:
        # Megatron-style axis alternation: INPUT projections contract d_model
        # (shard it over data -> FSDP-ish) and expand over model; OUTPUT
        # projections (w_down / wo / out...) contract the wide dim — shard it
        # over MODEL so the matmul partial-sums locally instead of
        # all-gathering [B,S,d_ff]-sized activations (measured; §Perf iter 5).
        out_proj = any(path.endswith(sfx) for sfx in
                       ("w_down", "wo", "out_proj", "rg/out", "dt_proj"))
        a, b = (model_axis, data_axis) if out_proj else (data_axis, model_axis)
        spec = [None] * nd
        spec[-2] = _maybe(mesh, a, shape[-2])
        spec[-1] = _maybe(mesh, b, shape[-1])
        return P(*spec)
    if nd >= 1 and shape[-1] >= 1024:            # wide vectors (A_log, D, ...)
        spec = [None] * nd
        spec[-1] = _maybe(mesh, model_axis, shape[-1])
        return P(*spec)
    return P()


def params_shardings(mesh: Mesh, params: PyTree, **kw) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append(NamedSharding(mesh, param_spec(mesh, key, leaf.shape, **kw)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_spec(mesh: Mesh, shape: Tuple[int, ...],
               batch_axes=("pod", "data")) -> P:
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    if not axes:
        return P()
    total = int(np.prod([_axis_size(mesh, a) for a in axes]))
    if shape[0] % total != 0:
        # fall back to axes that divide
        for sub in (("data",), ()):
            t = int(np.prod([_axis_size(mesh, a) for a in sub])) if sub else 1
            if shape[0] % t == 0:
                return P(sub if sub else None)
    return P(axes)


def batch_shardings(mesh: Mesh, batch: PyTree, batch_axes=("pod", "data")) -> PyTree:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(mesh, leaf.shape, batch_axes)),
        batch)


def strip_axes(spec: P, banned: Tuple[str, ...]) -> P:
    """Remove manual (worker) axes from a spec — constraints inside a
    partial-manual shard_map may only reference auto axes."""
    def clean(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a not in banned)
            return kept if kept else None
        return None if e in banned else e
    return P(*(clean(e) for e in spec))


def layer_constraint_fn(mesh: Mesh, banned_axes: Tuple[str, ...] = ()):
    """Build Model.layer_constraint: pins each per-layer param slice to the
    policy sharding (stacked spec minus the leading layer dim) so GSPMD keeps
    scan xs sharded through the loop boundary (per-iteration gathers)."""
    def constrain(p_slice):
        flat, treedef = jax.tree_util.tree_flatten_with_path(p_slice)
        out = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            stacked = param_spec(mesh, key, (0,) + tuple(leaf.shape))
            spec = P(*tuple(stacked)[1:]) if len(tuple(stacked)) > 1 else P()
            spec = strip_axes(P(*(tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec))))),
                              banned_axes)
            out.append(jax.lax.with_sharding_constraint(leaf, spec))
        return jax.tree_util.tree_unflatten(treedef, out)
    return constrain


def act_constraint_fn(mesh: Mesh, banned_axes: Tuple[str, ...] = (),
                      batch_axes=("pod", "data")):
    """Anchor activations [B, S, d]: batch over the (non-manual) data axes."""
    axes = tuple(a for a in batch_axes
                 if a in mesh.axis_names and a not in banned_axes)

    def constrain(x):
        if not axes or x.ndim < 2 or x.shape[0] % int(
                np.prod([_axis_size(mesh, a) for a in axes])):
            return x
        return jax.lax.with_sharding_constraint(
            x, P(axes, *([None] * (x.ndim - 1))))
    return constrain


def cache_spec(mesh: Mesh, path: str, shape: Tuple[int, ...],
               batch_axes=("pod", "data")) -> P:
    """KV caches [L, B, CL, KV, hd] -> batch over data axes, heads over model;
    SSM states [L, B, ...] -> batch over data, channels over model."""
    nd = len(shape)
    if nd >= 2:
        spec = [None] * nd
        b_ax = tuple(a for a in batch_axes if a in mesh.axis_names)
        total = int(np.prod([_axis_size(mesh, a) for a in b_ax])) if b_ax else 1
        if b_ax and shape[1] % total == 0:
            spec[1] = b_ax
        elif "data" in mesh.axis_names and shape[1] % _axis_size(mesh, "data") == 0:
            spec[1] = "data"
        # widest trailing dim over model
        cand = int(np.argmax(shape[2:])) + 2 if nd > 2 else None
        if cand is not None:
            spec[cand] = _maybe(mesh, "model", shape[cand])
        return P(*spec)
    return P()


def cache_shardings(mesh: Mesh, cache: PyTree, **kw) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append(NamedSharding(mesh, cache_spec(mesh, key, leaf.shape, **kw)))
    return jax.tree_util.tree_unflatten(treedef, out)
