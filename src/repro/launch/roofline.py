"""Roofline-term derivation from compiled dry-run artifacts.

  compute    = HLO_FLOPs / (chips * 197e12)          [bf16 peak / chip]
  memory     = HLO_bytes / (chips * 819e9)           [HBM bw / chip]
  collective = collective_bytes / (chips * 50e9)     [ICI link bw / chip]

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  collective_bytes
is parsed from the compiled HLO text: we sum the RESULT-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
instruction (a consistent per-device proxy: for all-reduce it is the tensor
size ~ bytes sent per device on a ring; for all-gather it is the bytes
received).  MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference)
per step.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link (1 effective link per chip assumed)
COLL_LAT = 2e-6           # per-collective launch/sync latency (s) — the term
                          # that makes many tiny rings latency-bound

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result like:  %x = f32[2,16]{1,0} all-gather(...)   OR tuple results
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind (deduping start/done pairs)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_start = set()
    for m in re.finditer(
            r"%?([\w.\-]*)\s*=\s*(\(?[^=]*?)\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
            r"(-start|-done)?\(", hlo_text):
        name, shapes, kind, phase = m.groups()
        if phase == "-done":
            continue            # counted at -start
        out[kind] += _shape_bytes(shapes)
    return out


def collective_dtype_bytes(hlo_text: str) -> Dict[tuple, int]:
    """Result-shape bytes keyed by (collective kind, dtype) — the wire-format
    guard uses this to pin the compressed ring to s8 payloads."""
    out: Dict[tuple, int] = {}
    for m in re.finditer(
            r"%?([\w.\-]*)\s*=\s*(\(?[^=]*?)\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
            r"(-start|-done)?\(", hlo_text):
        name, shapes, kind, phase = m.groups()
        if phase == "-done":
            continue            # counted at -start
        for dt, dims in _SHAPE_RE.findall(shapes):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            key = (kind, dt)
            out[key] = out.get(key, 0) + n * _DTYPE_BYTES[dt]
    return out


# ---------------------------------------------------------------------------
# Compressed-collective wire models (bucketed pipelined ring vs leaf loop)
# ---------------------------------------------------------------------------

def _codec_split(codec, shape) -> Dict[str, float]:
    """Per-message payload bytes by HLO dtype, read off the codec itself
    (``core/codec.Codec.wire_bytes``) — the single source of truth the
    models below share with the actual encoders."""
    return {dt: float(b) for dt, b in codec.wire_bytes(tuple(shape)).items()}


def _default_bucket_split(rows: int, row: int) -> Dict[str, float]:
    # the native row-scale squant wire: int8 levels + f32 per-row scales
    return {"s8": float(rows * row), "f32": float(4 * rows)}


def bucketed_wire_model(*, n_workers: int, n_buckets: int, rows: int,
                        row: int, codec=None, ici_bw: float = ICI_BW,
                        hbm_bw: float = HBM_BW,
                        coll_lat: float = COLL_LAT) -> Dict[str, float]:
    """Collective-bytes + exposed-comm-time model for the bucketed ring
    (core/dist.bucket_ring_reduce; geometry from core/bucketing.BucketLayout).

    Per hop, ONE stacked payload moves — for the default squant wire,
    ``n_buckets*rows*row`` int8 levels plus ``4*n_buckets*rows`` f32
    row-scales (one collective-permute per payload leaf).  Passing a
    ``core/codec.py`` codec derives the byte split from its
    ``wire_bytes((rows, row))`` instead (e.g. sparsify ships s32 indices +
    f32 values).  The scan body appears ONCE in HLO (``hlo_bytes_by_dtype``
    is what a static HLO parse sees) and executes ``n_workers-1`` times
    (``wire_bytes_per_step``).  The pipelined schedule overlaps each hop's
    wire time with the previous payload's dequant-accumulate, so only
    ``max(comm, dequant) - dequant`` per hop is *exposed*; the sequential
    schedule exposes all of it.
    """
    hops = n_workers - 1
    split = (_codec_split(codec, (rows, row)) if codec is not None
             else _default_bucket_split(rows, row))
    by_dtype = {dt: n_buckets * b for dt, b in split.items()}
    level_b = by_dtype.get("s8", 0.0)          # int8 levels (0 for identity)
    scale_b = sum(b for dt, b in by_dtype.items() if dt != "s8")
    payload = level_b + scale_b
    n_leaves = len([b for b in by_dtype.values() if b > 0])
    hop_comm = payload / ici_bw + max(n_leaves, 1) * coll_lat
    # dequant-accumulate: read payload + acc (4B/elem), write acc (4B/elem)
    elems = float(n_buckets * rows * row)
    hop_deq = (payload + 8.0 * elems) / hbm_bw
    return {
        "payload_bytes": payload,
        "hlo_s8_bytes": level_b,
        "hlo_scale_bytes": scale_b,
        "hlo_bytes_by_dtype": by_dtype,
        "wire_bytes_per_step": hops * payload,
        "comm_s": hops * hop_comm,
        "dequant_s": n_workers * hop_deq,
        "step_comm_serial_s": hops * (hop_comm + hop_deq) + hop_deq,
        "step_comm_pipelined_s": hops * max(hop_comm, hop_deq) + hop_deq,
        "exposed_comm_s": hops * max(0.0, hop_comm - hop_deq),
    }


def leaf_wire_model(leaf_shapes, *, n_workers: int, codec=None,
                    ici_bw: float = ICI_BW, hbm_bw: float = HBM_BW,
                    coll_lat: float = COLL_LAT) -> Dict[str, float]:
    """Same accounting for the legacy per-leaf sequential rings: every leaf
    pays its own N-1 blocking hops (one collective per payload leaf + a
    dequant stall each), and the unrolled hops all appear in static HLO."""
    hops = n_workers - 1
    by_dtype: Dict[str, float] = {}
    for s in leaf_shapes:
        split = (_codec_split(codec, s) if codec is not None
                 else _default_bucket_split(
                     int(np.prod(s[:-1])) if len(s) > 1 else 1,
                     int(s[-1]) if s else 1))
        for dt, b in split.items():
            by_dtype[dt] = by_dtype.get(dt, 0.0) + b
    level_b = by_dtype.get("s8", 0.0)
    scale_b = sum(b for dt, b in by_dtype.items() if dt != "s8")
    n_leaves = len(leaf_shapes)
    payload = level_b + scale_b
    elems = float(sum(int(np.prod(s)) if s else 1 for s in leaf_shapes))
    comm = hops * (payload / ici_bw + 2 * n_leaves * coll_lat)
    deq = n_workers * (payload + 8.0 * elems) / hbm_bw
    return {
        "payload_bytes": payload,
        "hlo_s8_bytes": hops * level_b,      # unrolled: every hop is an instr
        "hlo_scale_bytes": hops * scale_b,
        "hlo_bytes_by_dtype": {dt: hops * b for dt, b in by_dtype.items()},
        "wire_bytes_per_step": hops * payload,
        "comm_s": comm,
        "dequant_s": deq,
        "step_comm_serial_s": comm + deq,
        "step_comm_pipelined_s": comm + deq,     # nothing overlaps
        "exposed_comm_s": comm,
    }


def wire_bytes_match(hlo_text: str, model: Dict[str, float], *,
                     tol: float = 0.10) -> Dict[str, float]:
    """Measured-vs-model check for the compressed ring's HLO wire format.

    Returns {measured_s8, measured_scale_f32, model_s8, rel_err, ok,
    by_dtype}.  Models carrying ``hlo_bytes_by_dtype`` (codec-derived) are
    checked per payload dtype: every dtype the codec ships must appear as
    collective-permute bytes within ``tol``.  Legacy models (bare
    ``hlo_s8_bytes``) keep the original s8-only check — the guard that
    catches the ~256x replication blowup documented in
    ``artemis_aggregate`` from silently regressing.
    """
    by = collective_dtype_bytes(hlo_text)
    s8 = float(by.get(("collective-permute", "s8"), 0))
    f32 = float(by.get(("collective-permute", "f32"), 0))
    out = {"measured_s8": s8, "measured_scale_f32": f32}
    want_by = model.get("hlo_bytes_by_dtype")
    if want_by:
        checks = {}
        ok = True
        worst = 0.0
        for dt, want in want_by.items():
            if want <= 0:
                continue
            got = float(by.get(("collective-permute", dt), 0))
            rel = abs(got - want) / max(want, 1.0)
            checks[dt] = {"measured": got, "model": float(want), "rel_err": rel}
            worst = max(worst, rel)
            ok = ok and rel <= tol and got > 0
        out.update({"model_s8": float(want_by.get("s8", 0.0)),
                    "rel_err": worst, "ok": ok, "by_dtype": checks})
        return out
    want = float(model["hlo_s8_bytes"])
    rel = abs(s8 - want) / max(want, 1.0)
    out.update({"model_s8": want, "rel_err": rel,
                "ok": rel <= tol and s8 > 0})
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    kind: str                       # train | prefill | decode
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: Dict[str, int]
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0

    def finalize(self):
        total_coll = float(sum(self.coll_bytes.values()))
        # cost_analysis flops/bytes are per-device after SPMD partitioning
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = total_coll / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        # both model_flops and hlo_flops are per-device here
        self.useful_ratio = self.model_flops / max(self.hlo_flops, 1.0)
        return self

    def as_dict(self):
        d = dataclasses.asdict(self)
        return d


def count_params(params_tree) -> int:
    import jax
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params_tree)))


def active_params(cfg, params_tree) -> float:
    """Active parameter count: MoE experts scaled by top_k / n_experts."""
    import jax
    total, expert_total = 0.0, 0.0
    flat = jax.tree_util.tree_flatten_with_path(params_tree)[0]
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = float(np.prod(leaf.shape))
        if "moe" in key and "router" not in key:
            expert_total += n
        else:
            total += n
    if cfg.n_experts:
        total += expert_total * cfg.top_k / cfg.n_experts
    return total


def model_flops(cfg, params_tree, kind: str, batch: int, seq: int) -> float:
    n_active = active_params(cfg, params_tree)
    if kind == "train":
        return 6.0 * n_active * batch * seq
    if kind == "prefill":
        return 2.0 * n_active * batch * seq
    return 2.0 * n_active * batch * 1      # decode: one token


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | dist | kind | compute (s) | memory (s) | "
           "collective (s) | dominant | MODEL/HLO flops |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('dist', 'none')} | {r['kind']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} |")
    return hdr + "\n".join(lines)
