"""Mamba-1 selective SSM block (falcon-mamba architecture).

TPU adaptation: the CUDA selective-scan kernel becomes a *chunked associative
scan* — ``lax.scan`` over sequence chunks with a parallel
``lax.associative_scan`` inside each chunk, so the materialized state tensor
is [B, chunk, d_inner, d_state] instead of [B, S, d_inner, d_state]
(intractable at 4k x 8192 x 16).  Decode is the O(1) recurrent update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init


def d_inner(d_model, expand=2):
    return expand * d_model


def dt_rank(d_model):
    return -(-d_model // 16)


def init_mamba(key, d_model, d_state=16, d_conv=4, expand=2, dtype=jnp.float32):
    di, dr = d_inner(d_model, expand), dt_rank(d_model)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": _init(ks[0], (d_model, 2 * di), dtype=dtype),
        "conv_w": _init(ks[1], (d_conv, di), scale=0.5, dtype=dtype),
        "x_proj": _init(ks[2], (di, dr + 2 * d_state), dtype=dtype),
        "dt_proj": _init(ks[3], (dr, di), scale=dr**-0.5, dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                    * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)))).astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[5], (di, d_model), dtype=dtype),
    }


def _ssm_coeffs(p, xc, *, d_state):
    """xc: [..., S, di] (post-conv). Returns decay a=[...,S,di,N], drive bx."""
    dr = p["dt_proj"].shape[0]
    proj = xc @ p["x_proj"]                                   # [..., S, dr+2N]
    dt = jax.nn.softplus(proj[..., :dr] @ p["dt_proj"]
                         + p["dt_bias"].astype(xc.dtype))     # [..., S, di]
    B = proj[..., dr:dr + d_state]                            # [..., S, N]
    C = proj[..., dr + d_state:]                              # [..., S, N]
    A = -jnp.exp(p["A_log"]).astype(jnp.float32)              # [di, N]
    a = jnp.exp(dt[..., None].astype(jnp.float32) * A)        # [..., S, di, N]
    bx = (dt * xc)[..., :, :, None] * B[..., :, None, :]      # [..., S, di, N]
    return a, bx.astype(jnp.float32), C


def _chunk_scan(a, bx, h0):
    """Linear recurrence h_t = a_t * h_{t-1} + bx_t within one chunk.

    a, bx: [B, L, di, N]; h0: [B, di, N]. Returns (h_all [B, L, di, N], h_last).
    """
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    aa, hh = jax.lax.associative_scan(comb, (a, bx), axis=1)
    hh = hh + aa * h0[:, None]
    return hh, hh[:, -1]


def mamba_apply(p, x, *, d_state=16, chunk=256, state=None):
    """x: [B, S, d_model]. Train/prefill when state is None (full sequence);
    decode when S == 1 and state = dict(conv=[B, d_conv-1, di], h=[B, di, N]).

    Returns (y, new_state or None).
    """
    b, s, d = x.shape
    di = p["out_proj"].shape[0]
    xz = x @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]

    conv_w = p["conv_w"].astype(x.dtype)                       # [K, di]
    k = conv_w.shape[0]

    if state is None:
        # causal depthwise conv over the sequence
        pad = jnp.pad(xi, ((0, 0), (k - 1, 0), (0, 0)))
        xc = sum(pad[:, i:i + s] * conv_w[i] for i in range(k))
        xc = jax.nn.silu(xc)
        a, bx, C = _ssm_coeffs(p, xc, d_state=d_state)
        h0 = jnp.zeros((b, di, d_state), jnp.float32)
        if s % chunk == 0 and s > chunk:
            n = s // chunk
            a_c = a.reshape(b, n, chunk, di, d_state).swapaxes(0, 1)
            bx_c = bx.reshape(b, n, chunk, di, d_state).swapaxes(0, 1)

            def body(h, ab):
                hh, hl = _chunk_scan(ab[0], ab[1], h)
                return hl, hh
            _, hs = jax.lax.scan(body, h0, (a_c, bx_c))
            h_all = hs.swapaxes(0, 1).reshape(b, s, di, d_state)
        else:
            h_all, _ = _chunk_scan(a, bx, h0)
        y = jnp.einsum("bsdn,bsn->bsd", h_all.astype(x.dtype), C)
        new_state = None
    else:
        # O(1) decode step
        conv_buf = state["conv"]                               # [B, K-1, di]
        window = jnp.concatenate([conv_buf, xi], axis=1)       # [B, K, di]
        xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, conv_w))[:, None]
        a, bx, C = _ssm_coeffs(p, xc, d_state=d_state)
        h = a[:, 0] * state["h"] + bx[:, 0]                    # [B, di, N]
        y = jnp.einsum("bdn,bn->bd", h.astype(x.dtype), C[:, 0])[:, None]
        new_state = {"conv": window[:, 1:], "h": h}

    y = y + xi * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], new_state


def init_mamba_state(b, d_model, d_state=16, d_conv=4, expand=2, dtype=jnp.float32):
    di = d_inner(d_model, expand)
    return {"conv": jnp.zeros((b, d_conv - 1, di), dtype),
            "h": jnp.zeros((b, di, d_state), jnp.float32)}
