"""Mixture-of-Experts layer: top-k routing with capacity, sort/scatter dispatch.

Dispatch never materializes a one-hot [T, E, C] tensor (impossible at
32k x 32 shapes): token assignments are argsorted by expert id, ranked within
their expert via a cummax segment trick, and scattered into an [E*C, d]
buffer that feeds a grouped einsum against the stacked expert weights.
Dropped tokens (beyond capacity) pass through the residual only — standard
capacity-factor semantics.

The routing is natively batched over groups (NOT vmapped) so each
intermediate can carry an explicit sharding anchor: groups over 'data',
routing feature dims replicated, experts over 'model' for the grouped
einsum.  Without the anchors XLA's SPMD partitioner shards the
gather/scatter index dims and CHECK-crashes under partial-manual shard_map
(see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init

# Optional sharding anchors (set by the launcher via set_moe_sharding).
_MOE_AXES = {"enabled": False, "data": "data", "model": "model"}


def set_moe_sharding(enabled: bool, data_axis="data", model_axis="model"):
    _MOE_AXES.update(enabled=enabled, data=data_axis, model=model_axis)


def _anchor(x, spec):
    if not _MOE_AXES["enabled"]:
        return x
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) if mesh else {}

    def ok(entry, dim):
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes.get(a, 0) or 10**9
        return dim % n == 0

    clean = tuple(e if e is None or ok(e, x.shape[i]) else None
                  for i, e in enumerate(spec))
    return jax.lax.with_sharding_constraint(x, P(*clean))


_D = lambda: (_MOE_AXES["data"],)   # noqa: E731
_M = lambda: _MOE_AXES["model"]     # noqa: E731


def init_moe(key, d_model, d_ff, n_experts, activation, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "router": _init(ks[0], (d_model, n_experts), scale=0.02, dtype=jnp.float32),
        "w_up": _init(ks[1], (n_experts, d_model, d_ff), dtype=dtype),
        "w_down": _init(ks[2], (n_experts, d_ff, d_model), dtype=dtype),
    }
    if activation == "silu":
        p["w_gate"] = _init(ks[3], (n_experts, d_model, d_ff), dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Gather-free dispatch/combine with scatter-only custom VJPs.
#
# XLA's SPMD partitioner CHECK-crashes when evaluating gather strategies for
# computed indices on sharded operands inside a partial-manual shard_map —
# including the gathers autodiff creates as scatter TRANSPOSES.  Both
# directions are therefore written as scatters, using precomputed inverse
# maps (index-of-slot / slots-of-token).
# ---------------------------------------------------------------------------

import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=())
def _dispatch(updates, slot, inv_slot):
    """buf[g, slot[g,i]] = updates[g,i].  updates: [G,TK,d], slot: [G,TK]
    (overflow slot = cap1-1), inv_slot: [G,cap1] (sentinel TK).  -> [G,cap1,d]
    """
    g, tk, d = updates.shape
    cap1 = inv_slot.shape[1]
    g_idx = jnp.broadcast_to(jnp.arange(g)[:, None], (g, tk))
    return jnp.zeros((g, cap1, d), updates.dtype).at[g_idx, slot].set(updates)


def _dispatch_fwd(updates, slot, inv_slot):
    return _dispatch(updates, slot, inv_slot), (slot, inv_slot, updates.shape)


def _dispatch_bwd(res, ct_buf):
    slot, inv_slot, (g, tk, d) = res
    cap1 = inv_slot.shape[1]
    g_idx = jnp.broadcast_to(jnp.arange(g)[:, None], (g, cap1))
    # transpose as a SCATTER: ct_updates[inv_slot[s]] = ct_buf[s]
    ct_up = jnp.zeros((g, tk + 1, d), ct_buf.dtype).at[g_idx, inv_slot].set(ct_buf)
    return ct_up[:, :tk], None, None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@_ft.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _combine(y, w_of_slot, tok_of_slot, slots_of_tok, t):
    """out[g, tok_of_slot[g,s]] += y[g,s] * w_of_slot[g,s].

    y: [G,cap1,d]; tok_of_slot: [G,cap1] (sentinel t); slots_of_tok: [G,T,K]
    (sentinel cap1-1, the overflow slot).  -> [G,T,d]
    """
    g, cap1, d = y.shape
    g_idx = jnp.broadcast_to(jnp.arange(g)[:, None], (g, cap1))
    out = jnp.zeros((g, t + 1, d), y.dtype).at[g_idx, tok_of_slot].add(
        y * w_of_slot[..., None])
    return out[:, :t]


def _combine_fwd(y, w_of_slot, tok_of_slot, slots_of_tok, t):
    return (_combine(y, w_of_slot, tok_of_slot, slots_of_tok, t),
            (y, w_of_slot, slots_of_tok))


def _combine_bwd(t, res, ct_out):
    y, w_of_slot, slots_of_tok = res
    g, cap1, d = y.shape
    tt, k = slots_of_tok.shape[1:]
    # ct at each slot = ct_out at its token — via a SCATTER over (t, k):
    ct_tk = jnp.broadcast_to(ct_out[:, :, None, :], (g, tt, k, d)
                             ).reshape(g, tt * k, d)
    flat_slots = slots_of_tok.reshape(g, tt * k)
    g_idx = jnp.broadcast_to(jnp.arange(g)[:, None], (g, tt * k))
    ct_slots = jnp.zeros((g, cap1, d), ct_out.dtype).at[g_idx, flat_slots].set(ct_tk)
    # overflow slot (cap1-1) accumulates trash via collisions -> zero it
    ct_slots = ct_slots.at[:, cap1 - 1].set(0.0)
    ct_y = ct_slots * w_of_slot[..., None]
    ct_w = jnp.sum(ct_slots * y, axis=-1)
    return ct_y, ct_w, None, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def _route_grouped(p, xg, *, top_k: int, capacity: int, activation: str):
    """xg: [G, T, d] -> ([G, T, d], router probs [G, T, E]).

    GATHER-FREE dispatch/combine (see block comment above): ranks come from a
    one-hot exclusive cumsum (no sort), dispatch and combine are scatters
    with scatter-only custom VJPs.
    """
    g, t, d = xg.shape
    n_experts = p["router"].shape[1]
    tk = t * top_k
    cap1 = n_experts * capacity + 1          # +1 overflow slot

    logits = xg.astype(jnp.float32) @ p["router"]              # [G,T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)                    # [G,T,K]
    gate = (gate / jnp.sum(gate, -1, keepdims=True)).astype(xg.dtype)

    e_flat = _anchor(idx.reshape(g, tk), (_D(), None))         # [G,TK]
    gate_flat = gate.reshape(g, tk)
    # rank within expert via exclusive cumsum of the expert one-hot
    onehot = (e_flat[..., None] == jnp.arange(n_experts)[None, None, :]
              ).astype(jnp.int32)                              # [G,TK,E]
    prior = jnp.cumsum(onehot, axis=1) - onehot
    rank = jnp.sum(onehot * prior, axis=-1)                    # [G,TK]
    keep = rank < capacity
    slot = _anchor(jnp.where(keep, e_flat * capacity + rank, cap1 - 1),
                   (_D(), None))
    g_idx = jnp.broadcast_to(jnp.arange(g)[:, None], (g, tk))

    # inverse maps (int scatters; no gradients flow through these)
    inv_slot = jnp.full((g, cap1), tk, jnp.int32).at[g_idx, slot].set(
        jnp.broadcast_to(jnp.arange(tk)[None, :], (g, tk)))
    inv_slot = inv_slot.at[:, cap1 - 1].set(tk)     # overflow -> trash row
    tok_flat = jnp.broadcast_to(jnp.arange(tk)[None, :] // top_k, (g, tk))
    tok_of_slot = jnp.full((g, cap1), t, jnp.int32).at[g_idx, slot].set(tok_flat)
    tok_of_slot = tok_of_slot.at[:, cap1 - 1].set(t)
    slots_of_tok = jnp.where(keep, slot, cap1 - 1).reshape(g, t, top_k)

    # dispatch: pure broadcast (x repeated K times) + scatter
    updates = jnp.broadcast_to(xg[:, :, None, :], (g, t, top_k, d)
                               ).reshape(g, tk, d)
    updates = updates * keep[..., None].astype(xg.dtype)
    buf = _dispatch(updates, slot, inv_slot)
    eb = buf[:, :-1].reshape(g, n_experts, capacity, d)
    eb = _anchor(eb, (_D(), _M(), None, None))                 # expert parallel

    if activation == "silu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", eb, p["w_gate"])) * \
            jnp.einsum("gecd,edf->gecf", eb, p["w_up"])
    elif activation == "gelu":
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", eb, p["w_up"]))
    else:
        h = jnp.square(jax.nn.relu(jnp.einsum("gecd,edf->gecf", eb, p["w_up"])))
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"]).reshape(g, -1, d)
    y = _anchor(jnp.concatenate([y, jnp.zeros((g, 1, d), y.dtype)], axis=1),
                (_D(), None, None))                            # [G,cap1,d]

    # combine via inverse maps (scatter-only custom VJP); the gate weights
    # are dispatched the same way so their gradient reaches the router
    w_of_slot = _dispatch((gate_flat * keep.astype(xg.dtype))[..., None],
                          slot, inv_slot)[..., 0]
    out = _combine(y, w_of_slot, tok_of_slot, slots_of_tok, t)
    return _anchor(out, (_D(), None, None)), probs


def moe_apply(p, x, *, top_k: int, capacity_factor: float = 1.25,
              activation: str = "silu", group_size: int = 4096):
    """x: [B, S, d]. Routes per group of <= group_size tokens (per row when
    S >= group_size, else over the flattened batch).

    Returns (out, aux_loss) where aux_loss is the load-balance loss.
    """
    b, s, d = x.shape
    n_experts = p["router"].shape[1]

    if s >= group_size and s % group_size == 0:
        xg = x.reshape(b * (s // group_size), group_size, d)
    else:
        xg = x.reshape(1, b * s, d)
    tokens_per_group = xg.shape[1]
    capacity = max(int(tokens_per_group * top_k / n_experts * capacity_factor),
                   top_k)

    xg = _anchor(xg, (_D(), None, None))
    out, probs = _route_grouped(p, xg, top_k=top_k, capacity=capacity,
                                activation=activation)
    out = out.reshape(b, s, d)
    # load-balance aux loss (Switch-style smooth proxy)
    me = jnp.mean(probs, axis=(0, 1))
    aux = n_experts * jnp.sum(me * me)
    return out, aux.astype(jnp.float32)
