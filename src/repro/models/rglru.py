"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (per channel):
    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Like the SSM block, training uses a chunked associative scan; decode is O(1).
The full "recurrent block" wraps the RG-LRU with a short depthwise conv and a
gated linear unit, per the Griffin paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init

C_CONST = 8.0


def init_rglru(key, d_model, lru_width, d_conv=4, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    return {
        "in_x": _init(ks[0], (d_model, lru_width), dtype=dtype),
        "in_gate": _init(ks[1], (d_model, lru_width), dtype=dtype),
        "conv_w": _init(ks[2], (d_conv, lru_width), scale=0.5, dtype=dtype),
        "w_r": _init(ks[3], (lru_width, lru_width), dtype=dtype),
        "w_i": _init(ks[4], (lru_width, lru_width), dtype=dtype),
        # Lambda init so a^c in [0.9, 0.999] at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jax.random.uniform(ks[5], (lru_width,), jnp.float32,
                                        0.9, 0.999)) / C_CONST)),
        "out": _init(ks[6], (lru_width, d_model), dtype=dtype),
    }


def _gates(p, xc):
    r = jax.nn.sigmoid(xc @ p["w_r"])
    i = jax.nn.sigmoid(xc @ p["w_i"])
    decay = jax.nn.softplus(p["lam"]).astype(jnp.float32)
    log_a = -C_CONST * decay * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    drive = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = drive * (i * xc).astype(jnp.float32)
    return a, bx


def rglru_apply(p, x, *, chunk=256, state=None):
    """x: [B, S, d_model]. Returns (y, new_state or None); state as in ssm.py."""
    b, s, d = x.shape
    lw = p["out"].shape[0]
    xi = x @ p["in_x"]
    gate = jax.nn.gelu(x @ p["in_gate"])
    conv_w = p["conv_w"].astype(x.dtype)
    k = conv_w.shape[0]

    if state is None:
        pad = jnp.pad(xi, ((0, 0), (k - 1, 0), (0, 0)))
        xc = sum(pad[:, i:i + s] * conv_w[i] for i in range(k))
        a, bx = _gates(p, xc)
        h0 = jnp.zeros((b, lw), jnp.float32)

        def comb(u, v):
            a1, b1 = u
            a2, b2 = v
            return a1 * a2, a2 * b1 + b2

        if s % chunk == 0 and s > chunk:
            n = s // chunk
            a_c = a.reshape(b, n, chunk, lw).swapaxes(0, 1)
            bx_c = bx.reshape(b, n, chunk, lw).swapaxes(0, 1)

            def body(h, ab):
                aa, hh = jax.lax.associative_scan(comb, (ab[0], ab[1]), axis=1)
                hh = hh + aa * h[:, None]
                return hh[:, -1], hh
            _, hs = jax.lax.scan(body, h0, (a_c, bx_c))
            h_all = hs.swapaxes(0, 1).reshape(b, s, lw)
        else:
            aa, hh = jax.lax.associative_scan(comb, (a, bx), axis=1)
            h_all = hh + aa * h0[:, None]
        y = h_all.astype(x.dtype)
        new_state = None
    else:
        window = jnp.concatenate([state["conv"], xi], axis=1)
        xc = jnp.einsum("bkd,kd->bd", window, conv_w)[:, None]
        a, bx = _gates(p, xc)
        h = a[:, 0] * state["h"] + bx[:, 0]
        y = h.astype(x.dtype)[:, None]
        new_state = {"conv": window[:, 1:], "h": h}

    return (y * gate) @ p["out"], new_state


def init_rglru_state(b, lru_width, d_conv=4, dtype=jnp.float32):
    return {"conv": jnp.zeros((b, d_conv - 1, lru_width), dtype),
            "h": jnp.zeros((b, lru_width), jnp.float32)}
