"""Tiny many-leaf regression model for exercising the dist wire layer.

The transformer zoo in ``models/model.py`` is the right workload for
rooflines, but its forward pass dwarfs the aggregation cost on CPU — useless
for benchmarking the wire itself.  ``ToyMLP`` is the opposite: a dirt-cheap
forward over a pytree with MANY leaves of mixed shapes (matrices + biases),
so step wall-clock is dominated by exactly what the bucketed ring changes:
per-leaf collective count, dequant stalls, and payload layout.  Used by
``benchmarks/bucket_ring_bench.py`` and ``tests/helpers/bucket_scenarios.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class ToyMLP:
    """n_layers x (w [d,d] + b [d]) + head [d,1]: 2*n_layers+1 leaves.

    Implements the same ``loss(params, batch) -> (loss, {"nll", "aux"})``
    contract as ``models/model.Model``, so ``dist.make_train_step`` and
    ``dist.make_local_step`` consume it unchanged.
    """

    def __init__(self, n_layers: int = 12, d: int = 64):
        self.n_layers = n_layers
        self.d = d

    def init(self, key):
        params = {}
        for i in range(self.n_layers):
            kw, key = jax.random.split(key)
            params[f"layer_{i:02d}"] = {
                "w": jax.random.normal(kw, (self.d, self.d)) / self.d ** 0.5,
                "b": jnp.zeros((self.d,)),
            }
        params["head"] = jax.random.normal(key, (self.d, 1)) / self.d ** 0.5
        return params

    def loss(self, params, batch):
        x = batch["x"]
        for i in range(self.n_layers):
            p = params[f"layer_{i:02d}"]
            x = jnp.tanh(x @ p["w"] + p["b"])
        pred = x @ params["head"]
        mse = jnp.mean(jnp.square(pred - batch["y"]))
        return mse, {"nll": mse, "aux": jnp.zeros((), jnp.float32)}

    def batch(self, key, n: int = 32):
        kx, ky = jax.random.split(key)
        x = jax.random.normal(kx, (n, self.d))
        y = jnp.sum(jnp.sin(x[:, :4]), axis=-1, keepdims=True)
        y = y + 0.1 * jax.random.normal(ky, (n, 1))
        return {"x": x, "y": y}
