"""Shared neural-net layers: norms, RoPE, chunked attention, MLPs.

Everything is functional: ``init_*(key, ...) -> params`` and
``apply(params, x, ...) -> y``.  Attention is q-chunked (scan over query
blocks against the full K/V with masking) so 32k-token prefill never
materializes an S x S score matrix.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = (1.0 / jnp.sqrt(shape[0])) if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-6):
    # f32 ACCUMULATION via the reduction dtype (not by converting x: that
    # would make the whole-tensor backward cotangent f32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * (1.0 + gamma.astype(x.dtype))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta=10000.0):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq          # [..., S, half]
    ang = ang[..., None, :]                                        # [..., S, 1, half]
    cos, sin = jnp.cos(ang).astype(x.dtype), jnp.sin(ang).astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, optional sliding window), q-chunked
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, window: int, causal: bool):
    """[Sq, Sk] mask: causal, and |q-k| < window when window > 0."""
    if causal:
        m = q_pos[:, None] >= k_pos[None, :]
    else:
        # still exclude invalid (sentinel-position) cache slots
        m = k_pos[None, :] < _INVALID_POS
        m = jnp.broadcast_to(m, (q_pos.shape[0], k_pos.shape[0]))
    if window > 0:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


_INVALID_POS = jnp.iinfo(jnp.int32).max // 2


def attention(q, k, v, q_pos, k_pos, *, window: int = 0, q_chunk: int = 512,
              causal: bool = True, softmax_dtype=jnp.float32):
    """q: [B, Sq, H, D]; k, v: [B, Sk, KV, D]; grouped-query attention.

    Scans over query chunks; each chunk attends to the full K/V with a
    position mask — peak score memory is [B, H, q_chunk, Sk].
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    assert h % kv == 0
    rep = h // kv
    scale = d ** -0.5

    if sq <= q_chunk or sq % q_chunk != 0:
        return _attn_block(q, k, v, q_pos, k_pos, rep, scale, window, causal,
                           softmax_dtype)

    n_chunks = sq // q_chunk
    qs = q.reshape(b, n_chunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(b, n_chunks, q_chunk).transpose(1, 0, 2) if q_pos.ndim == 2 \
        else q_pos.reshape(n_chunks, q_chunk)

    def body(_, qc_pc):
        qc, pc = qc_pc
        o = _attn_block(qc, k, v, pc, k_pos, rep, scale, window, causal,
                        softmax_dtype)
        return None, o

    _, out = jax.lax.scan(body, None, (qs, ps))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


def _attn_block(q, k, v, q_pos, k_pos, rep, scale, window, causal, softmax_dtype):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    qg = q.reshape(b, sq, kv, rep, d)
    # grouped attention without materializing repeated K/V
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(softmax_dtype) * scale
    qp = q_pos if q_pos.ndim == 2 else jnp.broadcast_to(q_pos, (b,) + q_pos.shape)
    kp = k_pos if k_pos.ndim == 2 else jnp.broadcast_to(k_pos, (b,) + k_pos.shape)
    mask = jax.vmap(functools.partial(_mask, window=window, causal=causal))(qp, kp)
    scores = jnp.where(mask[:, None, None], scores, jnp.finfo(softmax_dtype).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v)
    return out.reshape(b, sq, h, d)


def init_attn(key, d_model, n_heads, n_kv, head_dim, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "wq": _init(ks[0], (d_model, n_heads * head_dim), dtype=dtype),
        "wk": _init(ks[1], (d_model, n_kv * head_dim), dtype=dtype),
        "wv": _init(ks[2], (d_model, n_kv * head_dim), dtype=dtype),
        "wo": _init(ks[3], (n_heads * head_dim, d_model), dtype=dtype),
    }


def init_attn_cache(b, cache_len, n_kv, head_dim, dtype=jnp.bfloat16):
    """Ring-buffer KV cache. ``pos`` holds the absolute position stored in
    each slot (sentinel = empty); sliding-window archs use cache_len=window."""
    return {
        "k": jnp.zeros((b, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((b, cache_len, n_kv, head_dim), dtype),
        "pos": jnp.full((cache_len,), _INVALID_POS, jnp.int32),
    }


def attn_apply(p, x, positions, *, n_heads, n_kv, head_dim, window=0,
               causal=True, rope_theta=10000.0, q_chunk=512,
               softmax_dtype=jnp.float32, cache=None,
               pos=None, cross_kv=None):
    """Self- or cross-attention.

    cache: optional ring-buffer cache (decode): the new k/v is written at slot
    ``pos % cache_len`` and attention runs against the whole cache using the
    absolute positions stored per slot.
    cross_kv: optional precomputed (k, v, k_pos) for encoder-decoder cross-attn.
    """
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    if cross_kv is not None:
        ck, cv, k_pos = cross_kv
        out = attention(q, ck, cv, positions, k_pos, window=0, causal=False,
                        q_chunk=q_chunk, softmax_dtype=softmax_dtype)
        return out.reshape(b, s, -1) @ p["wo"], None

    k = (x @ p["wk"]).reshape(b, s, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv, head_dim)
    if rope_theta:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    if cache is not None:
        cache_len = cache["k"].shape[1]
        slot = pos % cache_len
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.full((1,), pos, jnp.int32), slot, 0)
        out = attention(q, ck.astype(q.dtype), cv.astype(q.dtype), positions,
                        cpos, window=window, q_chunk=q_chunk,
                        softmax_dtype=softmax_dtype)
        return out.reshape(b, s, -1) @ p["wo"], {"k": ck, "v": cv, "pos": cpos}
    out = attention(q, k, v, positions, positions, window=window,
                    causal=causal, q_chunk=q_chunk, softmax_dtype=softmax_dtype)
    return out.reshape(b, s, -1) @ p["wo"], None


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, activation, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_up": _init(ks[0], (d_model, d_ff), dtype=dtype),
         "w_down": _init(ks[1], (d_ff, d_model), dtype=dtype)}
    if activation == "silu":                  # gated (SwiGLU)
        p["w_gate"] = _init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp_apply(p, x, activation):
    if activation == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif activation == "relu2":               # squared ReLU (nemotron)
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    elif activation == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    else:
        raise ValueError(activation)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes [B, S, V])
# ---------------------------------------------------------------------------

def chunked_xent(x, w_out, labels, *, chunk=512):
    """x: [B, S, d], w_out: [d, V], labels: [B, S] (-1 = ignore) -> mean NLL.

    Scans over sequence chunks so peak logits memory is [B, chunk, V].
    """
    b, s, d = x.shape
    if s <= chunk:
        n_tok, nll = _xent_block(x, w_out, labels)
        return nll / jnp.maximum(n_tok, 1.0)
    if s % chunk:                       # pad with ignored labels
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        s += pad
    n = s // chunk
    xs = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(acc, xl):
        xc, lc = xl
        n_tok, nll = _xent_block(xc, w_out, lc)
        return (acc[0] + n_tok, acc[1] + nll), None

    (tot_tok, tot_nll), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xs, ls))
    return tot_nll / jnp.maximum(tot_tok, 1.0)


def _xent_block(x, w_out, labels):
    logits = (x @ w_out).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    return jnp.sum(valid), jnp.sum((logz - gold) * valid)
