"""The unified model: init / train loss / prefill / decode for every family.

Layers are stacked and iterated with ``lax.scan`` (uniform families) so the
HLO stays small even for 88-layer configs; hybrid (patterned) families scan
over repeating groups.  All long-sequence paths are chunked (attention by
query block, cross-entropy by sequence block, SSM scans by chunk).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.config import ModelConfig

PyTree = Any


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, kind: str, key, cross: bool = False):
    ks = jax.random.split(key, 8)
    d, dt = cfg.d_model, cfg.pdtype
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), jnp.float32)}
    if kind in ("attn+mlp", "attn+moe", "la"):
        p["attn"] = L.init_attn(ks[0], d, cfg.n_heads, cfg.n_kv, cfg.hd, dtype=dt)
    if kind == "mamba":
        p["mamba"] = SSM.init_mamba(ks[1], d, cfg.ssm_state, cfg.ssm_conv,
                                    cfg.ssm_expand, dtype=dt)
        return p
    if kind == "rg":
        p["rg"] = RG.init_rglru(ks[2], d, cfg.lru_width, dtype=dt)
    p["ln2"] = jnp.zeros((d,), jnp.float32)
    if kind == "attn+moe":
        p["moe"] = MOE.init_moe(ks[3], d, cfg.d_ff, cfg.n_experts,
                                cfg.activation, dtype=dt)
    else:
        p["mlp"] = L.init_mlp(ks[4], d, cfg.d_ff, cfg.activation, dtype=dt)
    if cross:
        p["lnx"] = jnp.zeros((d,), jnp.float32)
        p["xattn"] = L.init_attn(ks[5], d, cfg.n_heads, cfg.n_kv, cfg.hd, dtype=dt)
    return p


def _apply_layer(cfg: ModelConfig, kind: str, p, x, positions, *, causal=True,
                 cache=None, pos=None, cross_kv=None):
    """Returns (x, aux_loss, new_cache)."""
    cd = cfg.cdtype
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    def norm(g, y):
        return L.rms_norm(y, g, cfg.norm_eps)

    if kind == "mamba":
        y, st = SSM.mamba_apply(_cast(p["mamba"], cd), norm(p["ln1"], x),
                                d_state=cfg.ssm_state, chunk=cfg.scan_chunk,
                                state=cache)
        return x + y, aux, st

    if kind == "rg":
        y, st = RG.rglru_apply(_cast(p["rg"], cd), norm(p["ln1"], x),
                               chunk=cfg.scan_chunk, state=cache)
        x = x + y
        new_cache = st
    else:  # attention kinds
        window = 0
        if kind == "la":
            window = cfg.local_window
        elif cfg.attn_kind == "sliding":
            window = cfg.window
        y, nc = L.attn_apply(_cast(p["attn"], cd), norm(p["ln1"], x), positions,
                             n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                             window=window, causal=causal,
                             rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                             softmax_dtype=jnp.dtype(cfg.softmax_dtype),
                             cache=cache, pos=pos)
        x = x + y
        new_cache = nc
        if "xattn" in p:
            y, _ = L.attn_apply(_cast(p["xattn"], cd), norm(p["lnx"], x),
                                positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                                head_dim=cfg.hd, causal=False,
                                rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                                cross_kv=cross_kv)
            x = x + y

    if "moe" in p:
        y, aux = MOE.moe_apply(_cast(p["moe"], cd), norm(p["ln2"], x),
                               top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               activation=cfg.activation,
                               group_size=cfg.moe_group)
        x = x + y
    elif "mlp" in p:
        x = x + L.mlp_apply(_cast(p["mlp"], cd), norm(p["ln2"], x), cfg.activation)
    return x, aux, new_cache


def _remat_policy(cfg):
    if cfg.remat_policy is None:
        return None
    return getattr(jax.checkpoint_policies, cfg.remat_policy)


def _cast(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype in (jnp.float32, jnp.bfloat16) else a,
        tree)


def _init_layer_cache(cfg: ModelConfig, kind: str, b: int, cache_len: int):
    if kind == "mamba":
        return SSM.init_mamba_state(b, cfg.d_model, cfg.ssm_state, cfg.ssm_conv,
                                    cfg.ssm_expand, dtype=cfg.cdtype)
    if kind == "rg":
        return RG.init_rglru_state(b, cfg.lru_width, dtype=cfg.cdtype)
    clen = cache_len
    if kind == "la":
        clen = min(cache_len, cfg.local_window)
    elif cfg.attn_kind == "sliding":
        clen = min(cache_len, cfg.window)
    return L.init_attn_cache(b, clen, cfg.n_kv, cfg.hd, dtype=cfg.cdtype)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()
        self.kinds = cfg.layer_kinds()
        self.uniform = len(set(self.kinds)) == 1
        if not self.uniform:
            period = len(cfg.pattern)
            self.n_groups = cfg.n_layers // period
            self.tail_kinds = self.kinds[self.n_groups * period:]
        # sharding hooks (set by the launcher; see launch/mesh.py):
        #   layer_constraint(p_slice) — pins the per-layer param slice inside
        #     the scan body so GSPMD all-gathers ONE layer per iteration
        #     instead of hoisting a full-stack gather out of the loop;
        #   act_constraint(x) — anchors activation batch sharding.
        self.layer_constraint = None
        self.act_constraint = None

    def set_sharding(self, layer_constraint=None, act_constraint=None):
        self.layer_constraint = layer_constraint
        self.act_constraint = act_constraint
        return self

    # -- init ---------------------------------------------------------------

    def init(self, key) -> PyTree:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        scale = 0.02
        params: Dict[str, Any] = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)
                      * scale).astype(cfg.pdtype),
            "unembed": (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab), jnp.float32)
                        * scale).astype(cfg.pdtype),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        cross = cfg.family == "encdec"
        if self.uniform:
            kind = self.kinds[0]
            lkeys = jax.random.split(ks[2], cfg.n_layers)
            per = [_init_layer(cfg, kind, k, cross=cross) for k in lkeys]
            params["layers"] = jax.tree.map(lambda *a: jnp.stack(a), *per)
        else:
            period = len(cfg.pattern)
            gkeys = jax.random.split(ks[6], self.n_groups * period).reshape(
                self.n_groups, period, -1)
            groups = []
            for j, kind in enumerate(cfg.pattern):
                per = [_init_layer(cfg, kind, gkeys[g, j]) for g in range(self.n_groups)]
                groups.append(jax.tree.map(lambda *a: jnp.stack(a), *per))
            params["groups"] = tuple(groups)
            tkeys = jax.random.split(ks[3], max(len(self.tail_kinds), 1))
            params["tail"] = [
                _init_layer(cfg, kind, tkeys[i])
                for i, kind in enumerate(self.tail_kinds)]
        if cfg.family == "encdec":
            ekeys = jax.random.split(ks[4], cfg.enc_layers)
            per = [_init_layer(cfg, "attn+mlp", k) for k in ekeys]
            params["encoder"] = jax.tree.map(lambda *a: jnp.stack(a), *per)
            params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.family in ("vlm", "encdec"):
            params["frontend_proj"] = (
                jax.random.normal(ks[5], (cfg.d_model, cfg.d_model), jnp.float32)
                * cfg.d_model ** -0.5).astype(cfg.pdtype)
        return params

    # -- shared stacks --------------------------------------------------------

    def _run_uniform(self, params, x, positions, *, causal=True, cache=None,
                     pos=None, cross_kv=None):
        cfg = self.cfg
        kind = self.kinds[0]

        def body(carry, inp):
            xx, aux = carry
            if cache is None and cross_kv is None:
                p_l, c_l, xkv = inp, None, None
            elif cache is None:
                p_l, xkv = inp
                c_l = None
            elif cross_kv is None:
                p_l, c_l = inp
                xkv = None
            else:
                p_l, c_l, xkv = inp
            if self.layer_constraint is not None:
                p_l = self.layer_constraint(p_l)
            if self.act_constraint is not None:
                xx = self.act_constraint(xx)
            # pin the residual stream (== the remat-saved stack) to the
            # compute dtype: anything that upcasts it to f32 doubles the
            # dominant memory-roofline term (measured on mistral-large)
            xx = xx.astype(cfg.cdtype)
            xx, aux_l, nc = _apply_layer(cfg, kind, p_l, xx, positions,
                                         causal=causal, cache=c_l, pos=pos,
                                         cross_kv=xkv)
            return (xx, aux + aux_l), nc

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        xs: Any = params
        if cache is not None and cross_kv is not None:
            xs = (params, cache, cross_kv)
        elif cache is not None:
            xs = (params, cache)
        elif cross_kv is not None:
            xs = (params, cross_kv)
        (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                            xs, unroll=min(cfg.scan_unroll,
                                                           cfg.n_layers))
        return x, aux, new_cache

    def _run_pattern(self, params, x, positions, *, cache=None, pos=None):
        cfg = self.cfg
        pattern = cfg.pattern

        def gbody(carry, inp):
            xx, aux = carry
            ps = inp[0] if cache is not None else inp
            cs = inp[1] if cache is not None else (None,) * len(pattern)
            if self.layer_constraint is not None:
                ps = tuple(self.layer_constraint(p) for p in ps)
            if self.act_constraint is not None:
                xx = self.act_constraint(xx)
            ncs = []
            for kind, p_l, c_l in zip(pattern, ps, cs):
                xx, a, nc = _apply_layer(cfg, kind, p_l, xx, positions,
                                         cache=c_l, pos=pos)
                aux = aux + a
                ncs.append(nc)
            return (xx, aux), tuple(ncs)

        if cfg.remat:
            gbody = jax.checkpoint(gbody, policy=_remat_policy(cfg))
        xs = (params["groups"], cache["groups"]) if cache is not None else params["groups"]
        (x, aux), new_gcache = jax.lax.scan(
            gbody, (x, jnp.zeros((), jnp.float32)), xs,
            unroll=min(cfg.scan_unroll, max(self.n_groups, 1)))
        new_tail = []
        for i, kind in enumerate(self.tail_kinds):
            c_l = cache["tail"][i] if cache is not None else None
            x, a, nc = _apply_layer(cfg, kind, params["tail"][i], x, positions,
                                    cache=c_l, pos=pos)
            aux = aux + a
            new_tail.append(nc)
        new_cache = ({"groups": new_gcache, "tail": new_tail}
                     if cache is not None else None)
        return x, aux, new_cache

    def _encode(self, params, frames):
        """Audio encoder over stubbed frame embeddings [B, F, d]."""
        cfg = self.cfg
        x = (frames.astype(cfg.cdtype) @ _cast(params["frontend_proj"], cfg.cdtype))
        pos = jnp.arange(frames.shape[1])[None, :].repeat(frames.shape[0], 0)

        def body(carry, p_l):
            xx, _ = carry
            xx, _, _ = _apply_layer(cfg, "attn+mlp", p_l, xx, pos, causal=False)
            return (xx, jnp.zeros((), jnp.float32)), None

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 params["encoder"])
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, params, enc_out):
        """Precompute per-decoder-layer cross K/V: [L, B, F, KV, hd]."""
        cfg = self.cfg
        b, f, _ = enc_out.shape
        wk = _cast(params["layers"]["xattn"]["wk"], cfg.cdtype)   # [L, d, kv*hd]
        wv = _cast(params["layers"]["xattn"]["wv"], cfg.cdtype)
        ck = jnp.einsum("bfd,ldh->lbfh", enc_out, wk).reshape(
            -1, b, f, cfg.n_kv, cfg.hd)
        cv = jnp.einsum("bfd,ldh->lbfh", enc_out, wv).reshape(
            -1, b, f, cfg.n_kv, cfg.hd)
        k_pos = jnp.arange(f)
        return (ck, cv, jnp.broadcast_to(k_pos, (ck.shape[0],) + k_pos.shape))

    # -- forward (train / prefill) -------------------------------------------

    def _forward(self, params, batch):
        """Returns (hidden states [B, S_total, d], aux, text_offset)."""
        cfg = self.cfg
        tok = batch["tokens"]
        # gather f32 rows locally, convert to bf16 BEFORE the model-axis
        # all-gather of activations (casting the whole table first makes XLA
        # gather-then-convert, moving f32 activations over ICI; §Perf)
        x = params["embed"][tok].astype(cfg.cdtype)
        offset = 0
        if cfg.family == "vlm":
            emb = batch["embeds"].astype(cfg.cdtype) @ _cast(
                params["frontend_proj"], cfg.cdtype)
            x = jnp.concatenate([emb, x], axis=1)
            offset = cfg.n_patches
        positions = jnp.arange(x.shape[1])[None, :].repeat(x.shape[0], 0)
        cross_kv = None
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["frames"])
            cross_kv = self._cross_kv(params, enc_out)
        if self.uniform:
            x, aux, _ = self._run_uniform(params["layers"], x, positions,
                                          cross_kv=cross_kv)
        else:
            x, aux, _ = self._run_pattern(params, x, positions)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux, offset

    def loss(self, params, batch):
        """Next-token cross-entropy (+ MoE aux). labels: tokens shifted."""
        cfg = self.cfg
        x, aux, off = self._forward(params, batch)
        tok = batch["tokens"]
        h = x[:, off:, :]                       # text region
        labels = jnp.concatenate(
            [tok[:, 1:], jnp.full((tok.shape[0], 1), -1, tok.dtype)], axis=1)
        nll = L.chunked_xent(h, _cast(params["unembed"], cfg.cdtype), labels,
                             chunk=cfg.xent_chunk)
        return nll + 0.01 * aux, {"nll": nll, "aux": aux}

    def prefill_logits(self, params, batch):
        """Forward returning ONLY the last position's logits [B, V]."""
        cfg = self.cfg
        x, _, _ = self._forward(params, batch)
        last = x[:, -1, :]
        return (last @ _cast(params["unembed"], cfg.cdtype)).astype(jnp.float32)

    # -- decode ---------------------------------------------------------------

    def init_cache(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        if self.uniform:
            kind = self.kinds[0]
            one = _init_layer_cache(cfg, kind, batch_size, cache_len)
            cache = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)
            return {"layers": cache}
        groups = []
        for kind in cfg.pattern:
            one = _init_layer_cache(cfg, kind, batch_size, cache_len)
            groups.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_groups,) + a.shape).copy(), one))
        tail = [_init_layer_cache(cfg, kind, batch_size, cache_len)
                for kind in self.tail_kinds]
        return {"groups": tuple(groups), "tail": tail}

    def decode_step(self, params, cache, token, pos, enc_out=None):
        """One serve step: token [B] int32, pos scalar int32.

        Returns (logits [B, V], new_cache).
        """
        cfg = self.cfg
        b = token.shape[0]
        x = _cast(params["embed"], cfg.cdtype)[token][:, None, :]   # [B, 1, d]
        positions = jnp.full((b, 1), pos, jnp.int32)
        cross_kv = None
        if cfg.family == "encdec":
            assert enc_out is not None
            cross_kv = self._cross_kv(params, enc_out)
        if self.uniform:
            x, _, nc = self._run_uniform(params["layers"], x, positions,
                                         cache=cache["layers"], pos=pos,
                                         cross_kv=cross_kv)
            new_cache = {"layers": nc}
        else:
            x, _, new_cache = self._run_pattern(params, x, positions,
                                                cache=cache, pos=pos)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x[:, 0] @ _cast(params["unembed"], cfg.cdtype)).astype(jnp.float32)
        return logits, new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
