"""ModelConfig: one dataclass describing every supported architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free families
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    activation: str = "silu"    # silu (gated) | gelu | relu2
    # attention
    attn_kind: str = "full"     # full | sliding
    window: int = 4096          # sliding-window size when attn_kind == sliding
    rope_theta: float = 10000.0
    q_chunk: int = 512
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 4096
    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    scan_chunk: int = 256
    # hybrid (RG-LRU + local attention)
    pattern: Tuple[str, ...] = ()      # period, e.g. ('rg','rg','la')
    lru_width: int = 0
    local_window: int = 2048
    # encoder-decoder (audio)
    enc_layers: int = 0
    n_frames: int = 0           # stubbed audio frame embeddings
    # VLM
    n_patches: int = 0          # stubbed vision patch embeddings
    # numerics / training
    norm_eps: float = 1e-6
    xent_chunk: int = 512
    softmax_dtype: str = "float32"   # attention score/softmax accumulation
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # None = full remat; else a jax.checkpoint_policies name, e.g.
    # "dots_with_no_batch_dims_saveable" (keep matmul outputs, recompute rest)
    remat_policy: Optional[str] = None
    # unroll factor for the layer scan. 1 = rolled (fast compile; XLA cost
    # analysis counts the body ONCE). Full unroll (= n_layers) gives honest
    # per-step roofline accounting at higher compile cost.
    scan_unroll: int = 1
    source: str = ""            # citation for the assigned config

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve long_500k decode (O(1)/O(window) state)?"""
        return self.family in ("ssm", "hybrid") or self.attn_kind == "sliding"

    @property
    def has_decode(self) -> bool:
        return True   # all assigned families have a decoder

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind list of length n_layers."""
        if self.family == "ssm":
            return ("mamba",) * self.n_layers
        if self.family == "hybrid":
            assert self.pattern
            reps = -(-self.n_layers // len(self.pattern))
            return (self.pattern * reps)[: self.n_layers]
        if self.family == "moe":
            return ("attn+moe",) * self.n_layers
        return ("attn+mlp",) * self.n_layers   # dense, vlm, encdec decoder

    def validate(self):
        if self.n_heads:
            assert self.n_heads % max(self.n_kv, 1) == 0, "GQA requires H % KV == 0"
        if self.family == "moe":
            assert self.n_experts > 0 and 0 < self.top_k <= self.n_experts
        if self.family == "ssm":
            assert self.ssm_state > 0
        if self.family == "hybrid":
            assert self.pattern and self.lru_width > 0
        if self.family == "encdec":
            assert self.enc_layers > 0 and self.n_frames > 0
        if self.family == "vlm":
            assert self.n_patches > 0
        return self
