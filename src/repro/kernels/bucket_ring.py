"""Fused dequant-accumulate for the bucketed pipelined ring.

Generalizes ``ring_sum.py`` (worker-stacked [N, M, C] payloads, all present
at once) to the [buckets, shard] layout of the bucketed wire: at each ring
hop exactly ONE stacked payload — ``q [B, R, C] int8`` levels plus
``scales [B, R, 1] f32`` per-row scales, one pair per bucket — arrives and
is folded into the resident f32 accumulator in a single pass:

    acc[b] += q[b] * scales[b]          (one HBM read of q/scales/acc,
                                         one HBM write of acc)

``core/dist.bucket_ring_reduce`` calls this once per hop *while the next
hop's collective-permute is already in flight* (the double-buffered carry),
so on real hardware the dequant hides under the wire latency.  On CPU the
kernels run in interpret mode, same as the rest of ``kernels/``.

``bucket_ring_sum`` is the all-at-once variant ([N, B, R, C] stacks, the
direct generalization of ``ring_sum.ring_sum``) used as the gather-style
oracle in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _acc_kernel(acc_ref, q_ref, s_ref, o_ref):
    o_ref[...] = acc_ref[...] + (q_ref[...].astype(jnp.float32)
                                 * s_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bucket_acc(acc: jax.Array, q: jax.Array, scales: jax.Array, *,
               block_rows: int = 0, interpret: bool = True) -> jax.Array:
    """One ring-hop fold: ``acc + dequant(q, scales)``.

    acc [B, R, C] f32, q [B, R, C] int8, scales [B, R, 1] f32 (per-row,
    matching ``core/dist.squant_encode`` vmapped over buckets).
    ``block_rows``: rows per grid block (0 = whole bucket per block; must
    divide R otherwise).

    In interpret mode with default blocking the grid is dropped entirely
    (one cell over the whole stack): each interpret-mode grid cell costs a
    dispatch, which at B x (R/br) cells per hop inside the scan ring
    dominated the CPU step (~8x this kernel, measured).  The result is
    bitwise identical; on real hardware the grid is what tiles the payload
    through VMEM, so it stays.
    """
    b, r, c = q.shape
    # named_scope: metadata-only tag so the kernel launch is findable on
    # the profiler timeline (repro.obs spans/Perfetto capture)
    if interpret and block_rows == 0:
        with jax.named_scope("bucket_acc"):
            return pl.pallas_call(
                _acc_kernel,
                out_shape=jax.ShapeDtypeStruct((b, r, c), jnp.float32),
                interpret=interpret,
            )(acc, q, scales)
    br = r if block_rows == 0 else block_rows
    assert r % br == 0, (q.shape, block_rows)
    with jax.named_scope("bucket_acc"):
        return pl.pallas_call(
            _acc_kernel,
            grid=(b, r // br),
            in_specs=[
                pl.BlockSpec((1, br, c), lambda i, j: (i, j, 0)),
                pl.BlockSpec((1, br, c), lambda i, j: (i, j, 0)),
                pl.BlockSpec((1, br, 1), lambda i, j: (i, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, br, c), lambda i, j: (i, j, 0)),
            out_shape=jax.ShapeDtypeStruct((b, r, c), jnp.float32),
            interpret=interpret,
        )(acc, q, scales)


def bucket_acc_ref(acc: jax.Array, q: jax.Array, scales: jax.Array):
    """Pure-jnp oracle for ``bucket_acc``."""
    return acc + q.astype(jnp.float32) * scales.astype(jnp.float32)


def _sum_kernel(q_ref, s_ref, o_ref, *, n: int):
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for i in range(n):                      # N is small (workers); unrolled
        acc += q_ref[i].astype(jnp.float32) * s_ref[i].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def bucket_ring_sum(q: jax.Array, scales: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """All-at-once reduce: q [N, B, R, C] int8, scales [N, B, R, 1] f32 ->
    [B, R, C] f32.  ``ring_sum.ring_sum`` generalized to the bucketed
    layout; the hop-by-hop ``bucket_acc`` chain must match it bitwise."""
    n, b, r, c = q.shape
    return pl.pallas_call(
        functools.partial(_sum_kernel, n=n),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((n, 1, r, c), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((n, 1, r, 1), lambda i: (0, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, c), jnp.float32),
        interpret=interpret,
    )(q, scales)


def bucket_ring_sum_ref(q: jax.Array, scales: jax.Array) -> jax.Array:
    return jnp.sum(q.astype(jnp.float32) * scales.astype(jnp.float32),
                   axis=0)
