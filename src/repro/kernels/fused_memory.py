"""Fused Artemis worker-side kernel.

Computes, in ONE pass over HBM (reads g, h, u; writes q, scale, h_new):

    delta  = g - h
    (q,sc) = squant_encode(delta)           # per-tile s-quantization
    h_new  = h + alpha * dequant(q, sc)     # memory update (Algorithm 1, line 4)

Unfused this costs 3 reads + 2 writes of gradient-sized buffers plus the
intermediate ``delta`` roundtrip; fused it is 3 reads + 2 writes total with
delta/levels kept in VMEM — the memory-roofline win measured in
benchmarks/kernel_bench.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.squant import DEFAULT_BLOCK, _grid


def _fused_kernel(g_ref, h_ref, u_ref, alpha_ref, q_ref, scale_ref, h_new_ref,
                  *, s: int):
    g = g_ref[...]
    h = h_ref[...]
    delta = (g - h).astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(delta * delta))
    # non-finite tile => zero scale: wire payload decodes to 0 and the memory
    # update below degrades to h_new = h (matches squant.py's clamp)
    scale = jnp.where(jnp.isfinite(norm), norm / s, 0.0)
    scale_ref[0, 0] = scale
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(delta) / safe * s
    low = jnp.floor(r)
    psi = low + (u_ref[...].astype(jnp.float32) < (r - low)).astype(jnp.float32)
    q = (jnp.sign(delta) * psi).astype(jnp.int8)
    q_ref[...] = q
    alpha = alpha_ref[0, 0].astype(g.dtype)
    h_new_ref[...] = h + alpha * (q.astype(g.dtype) * scale.astype(g.dtype))


@functools.partial(jax.jit, static_argnames=("s", "block", "interpret"))
def fused_memory_update(g: jax.Array, h: jax.Array, u: jax.Array,
                        alpha: jax.Array, *, s: int = 1, block=DEFAULT_BLOCK,
                        interpret: bool = True):
    """Returns (q int8, scales f32 grid, h_new)."""
    assert 1 <= s <= 126, s
    bm, bn = block
    gm, gn = _grid(g.shape, block)
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_fused_kernel, s=s),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(g.shape, jnp.int8),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
            jax.ShapeDtypeStruct(g.shape, g.dtype),
        ],
        interpret=interpret,
    )(g, h, u, alpha)
