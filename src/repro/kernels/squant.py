"""Pallas TPU kernels for per-tile stochastic s-quantization.

TPU-native adaptation of paper Definition 1 (see DESIGN.md §3): one scale per
(bm x bn) VMEM-resident tile instead of one global L2 norm, so encode is a
single HBM pass with no global pre-reduction.  Wire format: int8 levels +
one f32 scale per tile (levels in [-(s+1), s+1], so s <= 126).

On a real TPU the uniform randomness would come from ``pltpu.prng_random_bits``
seeded per tile (zero extra HBM traffic); the CPU interpreter has no lowering
for the TPU PRNG primitives, so ``u`` is passed as an operand here and the
device-PRNG variant is left as the documented production path.

Block shapes default to (256, 256) = 256 KiB f32 in + 64 KiB int8 out per
buffer — comfortably double-bufferable in 16 MiB VMEM, and (8,128)/(32,128)
tile-aligned for f32/int8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (256, 256)


def _encode_kernel(x_ref, u_ref, q_ref, scale_ref, *, s: int):
    # norms & thresholds in f32 regardless of input dtype (bf16-safe)
    x = x_ref[...].astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(x * x))
    # an all-NaN/Inf tile must not ship a NaN scale: clamp to 0 so decode is
    # exactly 0 (finite) no matter what the int8 levels hold
    scale_ref[0, 0] = jnp.where(jnp.isfinite(norm), norm / s, 0.0)
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(x) / safe * s
    low = jnp.floor(r)
    psi = low + (u_ref[...].astype(jnp.float32) < (r - low)).astype(jnp.float32)
    q_ref[...] = (jnp.sign(x) * psi).astype(jnp.int8)


def _decode_kernel(q_ref, scale_ref, o_ref, *, dtype):
    o_ref[...] = q_ref[...].astype(dtype) * scale_ref[0, 0].astype(dtype)


def _dequant_apply_kernel(w_ref, q_ref, scale_ref, gamma_ref, o_ref):
    dtype = w_ref.dtype
    o_ref[...] = w_ref[...] - gamma_ref[0, 0].astype(dtype) * (
        q_ref[...].astype(dtype) * scale_ref[0, 0].astype(dtype))


def _grid(mshape, block):
    (m, n), (bm, bn) = mshape, block
    assert m % bm == 0 and n % bn == 0, (mshape, block)
    return (m // bm, n // bn)


@functools.partial(jax.jit, static_argnames=("s", "block", "interpret"))
def squant_encode(x: jax.Array, u: jax.Array, *, s: int = 1,
                  block=DEFAULT_BLOCK, interpret: bool = True):
    """x, u: [M, N] (block-multiple). Returns (q int8 [M,N], scales f32 grid)."""
    assert 1 <= s <= 126, s
    bm, bn = block
    gm, gn = _grid(x.shape, block)
    return pl.pallas_call(
        functools.partial(_encode_kernel, s=s),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.int8),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ],
        interpret=interpret,
    )(x, u)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "dtype"))
def squant_decode(q: jax.Array, scales: jax.Array, *, block=DEFAULT_BLOCK,
                  dtype=jnp.float32, interpret: bool = True):
    bm, bn = block
    gm, gn = _grid(q.shape, block)
    return pl.pallas_call(
        functools.partial(_decode_kernel, dtype=dtype),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(q.shape, dtype),
        interpret=interpret,
    )(q, scales)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequant_apply(w: jax.Array, q: jax.Array, scales: jax.Array,
                  gamma: jax.Array, *, block=DEFAULT_BLOCK,
                  interpret: bool = True):
    """Fused optimizer apply: w' = w - gamma * dequant(q, scales)."""
    bm, bn = block
    gm, gn = _grid(w.shape, block)
    gamma = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _dequant_apply_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=interpret,
    )(w, q, scales, gamma)
