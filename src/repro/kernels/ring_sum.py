"""Fused dequant-accumulate across N compressed worker payloads.

The Artemis aggregation hot loop: after the int8 ring delivers every worker's
(levels, scales), each device computes  sum_i q_i * scale_i  — unfused this
reads N int8 buffers + writes N-1 f32 partials; fused it is one pass:
VMEM-resident accumulator, one f32 write.

Layout: q [N, M, C] int8, scales [N, M, 1] f32 (per-row, matching
core/dist.squant_encode), output [M, C] f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ring_sum_kernel(q_ref, s_ref, o_ref, *, n: int):
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for i in range(n):                       # N is small (workers); unrolled
        acc += q_ref[i].astype(jnp.float32) * s_ref[i].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ring_sum(q: jax.Array, scales: jax.Array, *, block=(256, 256),
             interpret: bool = True) -> jax.Array:
    """q: [N, M, C] int8 (M, C block-multiples), scales: [N, M, 1] f32."""
    n, m, c = q.shape
    bm, bc = block
    assert m % bm == 0 and c % bc == 0, (q.shape, block)
    return pl.pallas_call(
        functools.partial(_ring_sum_kernel, n=n),
        grid=(m // bm, c // bc),
        in_specs=[
            pl.BlockSpec((n, bm, bc), lambda i, j: (0, i, j)),
            pl.BlockSpec((n, bm, 1), lambda i, j: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, c), jnp.float32),
        interpret=interpret,
    )(q, scales)


def ring_sum_ref(q: jax.Array, scales: jax.Array) -> jax.Array:
    """Pure-jnp oracle."""
    return jnp.sum(q.astype(jnp.float32) * scales.astype(jnp.float32), axis=0)
