"""Pure-jnp oracles for the Pallas kernels.

All references operate on 2-D arrays already padded to block multiples,
with per-(bm x bn)-block scales — the exact layout the kernels produce, so
tests can require bit-exact agreement (same uniform randomness ``u``).
"""
from __future__ import annotations

import jax.numpy as jnp


def _blockify(x: jnp.ndarray, bm: int, bn: int):
    """[M, N] -> [M//bm, N//bn, bm, bn]."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, bm, bn)
    return (x.reshape(m // bm, bm, n // bn, bn).transpose(0, 2, 1, 3))


def _unblockify(b: jnp.ndarray):
    gm, gn, bm, bn = b.shape
    return b.transpose(0, 2, 1, 3).reshape(gm * bm, gn * bn)


def squant_encode_ref(x: jnp.ndarray, u: jnp.ndarray, s: int, bm: int, bn: int):
    """Per-block stochastic s-quantization.

    Returns (q: int8 [M,N], scales: f32 [M//bm, N//bn]) with
    dequant(q, scales) = q * scale_of_block, scale = ||block||_2 / s.
    """
    xb = _blockify(x, bm, bn).astype(jnp.float32)
    ub = _blockify(u, bm, bn).astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(xb**2, axis=(-2, -1)))            # [gm, gn]
    scales = norms / s
    safe = jnp.where(norms > 0, norms, 1.0)[..., None, None]
    r = jnp.abs(xb) / safe * s
    low = jnp.floor(r)
    psi = low + (ub < (r - low)).astype(jnp.float32)
    q = (jnp.sign(xb) * psi).astype(jnp.int8)
    return _unblockify(q), scales.astype(jnp.float32)


def squant_decode_ref(q: jnp.ndarray, scales: jnp.ndarray, bm: int, bn: int,
                      dtype=jnp.float32):
    qb = _blockify(q, bm, bn).astype(dtype)
    return _unblockify(qb * scales[..., None, None].astype(dtype))


def fused_memory_ref(g: jnp.ndarray, h: jnp.ndarray, u: jnp.ndarray,
                     alpha: float, s: int, bm: int, bn: int):
    """delta = g - h; (q, scales) = encode(delta); h' = h + alpha * deq(q).

    One logical HBM pass (the point of the fused kernel).
    Returns (q, scales, h_new).
    """
    delta = g - h
    q, scales = squant_encode_ref(delta, u, s, bm, bn)
    h_new = h + alpha * squant_decode_ref(q, scales, bm, bn, dtype=g.dtype)
    return q, scales, h_new


def dequant_apply_ref(w: jnp.ndarray, q: jnp.ndarray, scales: jnp.ndarray,
                      gamma: float, bm: int, bn: int):
    """w' = w - gamma * deq(q, scales)."""
    return w - gamma * squant_decode_ref(q, scales, bm, bn, dtype=w.dtype)
