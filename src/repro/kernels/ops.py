"""Public shape-agnostic API over the Pallas compression kernels.

Handles packing arbitrary-shaped arrays (or whole gradient pytrees) into the
padded 2-D block layout the kernels expect, PRNG, and interpret-mode
auto-detection (interpret on CPU; compiled Mosaic on TPU).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import fused_memory as _fm
from repro.kernels import squant as _sq

DEFAULT_BLOCK = _sq.DEFAULT_BLOCK


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        from repro.kernels import default_interpret
        return default_interpret()
    return interpret


def _pack(x: jax.Array, block) -> Tuple[jax.Array, Tuple[int, ...]]:
    """Flatten + zero-pad to an [M, bn] block-multiple 2-D layout."""
    bm, bn = block
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = bn
    rows = -(-n // cols)                    # ceil
    rows = -(-rows // bm) * bm              # round rows up to bm
    padded = jnp.zeros((rows * cols,), x.dtype).at[:n].set(flat)
    return padded.reshape(rows, cols), x.shape


def _unpack(x2d: jax.Array, shape) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return x2d.reshape(-1)[:n].reshape(shape)


class Compressed(NamedTuple):
    """Wire format: int8 levels + f32 per-tile scales + original shape info."""
    q: jax.Array          # int8 [M, N]
    scales: jax.Array     # f32 [M//bm, N//bn]

    @property
    def wire_bytes(self) -> int:
        return self.q.size + 4 * self.scales.size


def encode(key: jax.Array, x: jax.Array, *, s: int = 1, block=DEFAULT_BLOCK,
           interpret: Optional[bool] = None) -> Tuple[Compressed, Tuple[int, ...]]:
    x2d, shape = _pack(x, block)
    u = jax.random.uniform(key, x2d.shape, dtype=x2d.dtype)
    q, scales = _sq.squant_encode(x2d, u, s=s, block=block,
                                  interpret=_auto_interpret(interpret))
    return Compressed(q, scales), shape


def decode(c: Compressed, shape, *, block=DEFAULT_BLOCK, dtype=jnp.float32,
           interpret: Optional[bool] = None) -> jax.Array:
    out = _sq.squant_decode(c.q, c.scales, block=block, dtype=dtype,
                            interpret=_auto_interpret(interpret))
    return _unpack(out, shape)


def compress(key: jax.Array, x: jax.Array, *, s: int = 1, block=DEFAULT_BLOCK,
             interpret: Optional[bool] = None) -> jax.Array:
    """Round-trip encode+decode — an unbiased Assumption-5 compressor usable
    anywhere a `Compressor.compress` is expected."""
    c, shape = encode(key, x, s=s, block=block, interpret=interpret)
    return decode(c, shape, block=block, dtype=x.dtype, interpret=interpret)


def memory_update(key: jax.Array, g: jax.Array, h: jax.Array, alpha,
                  *, s: int = 1, block=DEFAULT_BLOCK,
                  interpret: Optional[bool] = None):
    """Fused Artemis worker step on an arbitrary-shaped gradient.

    Returns (delta_hat (decoded, g.shape), h_new (g.shape), compressed wire).
    """
    g2d, shape = _pack(g, block)
    h2d, _ = _pack(h, block)
    u = jax.random.uniform(key, g2d.shape, dtype=g2d.dtype)
    itp = _auto_interpret(interpret)
    q, scales, h_new2d = _fm.fused_memory_update(g2d, h2d, u, alpha, s=s,
                                                 block=block, interpret=itp)
    c = Compressed(q, scales)
    delta_hat = decode(c, shape, block=block, dtype=g.dtype, interpret=itp)
    return delta_hat, _unpack(h_new2d, shape), c


def apply_update(w: jax.Array, c: Compressed, gamma, shape=None, *,
                 block=DEFAULT_BLOCK, interpret: Optional[bool] = None) -> jax.Array:
    """Fused w' = w - gamma * dequant(c)."""
    shape = w.shape if shape is None else shape
    w2d, _ = _pack(w, block)
    out = _sq.dequant_apply(w2d, c.q, c.scales, gamma, block=block,
                            interpret=_auto_interpret(interpret))
    return _unpack(out, shape)


# ---------------------------------------------------------------------------
# Pytree helpers (gradient trees)
# ---------------------------------------------------------------------------

def tree_compress(key: jax.Array, tree, *, s: int = 1, block=DEFAULT_BLOCK,
                  interpret: Optional[bool] = None):
    """Apply the round-trip compressor leaf-wise with independent keys."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [compress(k, leaf, s=s, block=block, interpret=interpret)
           for k, leaf in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def tree_memory_update(key: jax.Array, grads, h, alpha, *, s: int = 1,
                       block=DEFAULT_BLOCK, interpret: Optional[bool] = None):
    """Fused memory update over a gradient pytree. Returns (delta_hat, h_new)."""
    gl, treedef = jax.tree.flatten(grads)
    hl = treedef.flatten_up_to(h)
    keys = jax.random.split(key, len(gl))
    dh, hn = [], []
    for k, g, hh in zip(keys, gl, hl):
        d, h2, _ = memory_update(k, g, hh, alpha, s=s, block=block,
                                 interpret=interpret)
        dh.append(d)
        hn.append(h2)
    return jax.tree.unflatten(treedef, dh), jax.tree.unflatten(treedef, hn)
