from repro.kernels import (  # noqa: F401
    bucket_ring, fused_memory, ops, ref, ring_sum, squant)
