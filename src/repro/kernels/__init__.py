from repro.kernels import ops, ref, squant, fused_memory, ring_sum  # noqa: F401
