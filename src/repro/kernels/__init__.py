import os

from repro.kernels import (  # noqa: F401
    bucket_ring, fused_memory, ops, ref, ring_sum, squant)


def default_interpret() -> bool:
    """Resolve Pallas interpret mode for kernel call sites that do not pin it.

    ``REPRO_INTERPRET=1/0`` forces interpret on/off (e.g. force-compile
    Mosaic in CI, or interpret-debug on a TPU host); unset/``auto`` selects
    interpret on CPU and compiled Mosaic on accelerator backends.
    """
    env = os.environ.get("REPRO_INTERPRET", "").strip().lower()
    if env in ("1", "true", "yes", "on"):
        return True
    if env in ("0", "false", "no", "off"):
        return False
    import jax
    return jax.default_backend() == "cpu"
