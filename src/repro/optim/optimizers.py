"""Minimal functional optimizers (pytree-native, sharding-transparent).

The paper's algorithm is SGD; Adam is provided as the beyond-paper option —
Artemis composes with either because compression acts on the *gradient
aggregate* before the optimizer sees it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, jax.Array], Tuple[PyTree, PyTree]]
    # update(grads, opt_state, step) -> (updates, new_state); caller applies
    # params - lr_schedule(step) * updates is folded in already.


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return _tmap(jnp.zeros_like, params)

    def update(grads, state, step, params=None):
        del step
        if weight_decay and params is not None:
            grads = _tmap(lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            return _tmap(lambda g: lr * g, grads), ()
        new_m = _tmap(lambda m, g: momentum * m + g, state, grads)
        return _tmap(lambda m: lr * m, new_m), new_m

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = _tmap(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z)}

    def update(grads, state, step, params=None):
        t = step.astype(jnp.float32) + 1.0
        g32 = _tmap(lambda g: g.astype(jnp.float32), grads)
        m = _tmap(lambda m, g: b1 * m + (1 - b1) * g, state["m"], g32)
        v = _tmap(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], g32)
        mh = _tmap(lambda m_: m_ / (1 - b1 ** t), m)
        vh = _tmap(lambda v_: v_ / (1 - b2 ** t), v)
        upd = _tmap(lambda m_, v_: lr * m_ / (jnp.sqrt(v_) + eps), mh, vh)
        if weight_decay and params is not None:
            upd = _tmap(lambda u, p: u + lr * weight_decay * p.astype(jnp.float32),
                        upd, params)
        return upd, {"m": m, "v": v}

    return Optimizer(init, update)


def cosine_lr(base: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base * jnp.where(s < warmup, warm, cos)
    return sched
