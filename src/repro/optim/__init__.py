from repro.optim.optimizers import Optimizer, adam, sgd, cosine_lr  # noqa: F401
