"""Minimal sharding-aware checkpointer: npz payload + JSON manifest.

Saves a pytree of jax.Arrays as flattened npz entries keyed by tree path;
restores onto the caller-provided sharding (device_put per leaf).  No orbax
in this offline container — the format is deliberately trivial and
append-only (step-numbered directories + a LATEST pointer).

Crash safety: every file (``arrays.npz``, ``manifest.json``, ``LATEST``) is
written to a temp name and atomically renamed, and ``LATEST`` is only
advanced after the step directory is complete — a process killed mid-save
leaves the previous checkpoint fully readable.  ``restore`` validates the
manifest (key set, shapes, dtypes) against the target tree up front and
raises a single clear ``ValueError`` instead of a shape assert deep in
``device_put``.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_SAFE.sub("_", str(getattr(p, "key", getattr(p, "idx", p))))
                       for p in path)
        out[key or "_root"] = leaf
    return out, treedef


def _atomic_write(path: str, write_fn):
    """Write via a same-directory temp file + atomic rename."""
    tmp = path + f".tmp.{os.getpid()}"
    try:
        write_fn(tmp)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save(ckpt_dir: str, step: int, tree: PyTree, extra: Optional[dict] = None):
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write_npz(tmp):
        # np.savez appends .npz to names without it; write with an explicit
        # handle so the temp name is exactly what we rename
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)

    _atomic_write(os.path.join(d, "arrays.npz"), _write_npz)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }

    def _write_json(tmp):
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)

    _atomic_write(os.path.join(d, "manifest.json"), _write_json)

    def _write_latest(tmp):
        with open(tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())

    # LATEST moves last: readers never see a pointer to a partial step dir
    _atomic_write(os.path.join(ckpt_dir, "LATEST"), _write_latest)
    return d


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """Load the manifest of ``step`` (default: LATEST) without the arrays."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    if not os.path.exists(path):
        raise FileNotFoundError(f"missing manifest: {path}")
    with open(path) as f:
        return json.load(f)


def _validate(manifest: dict, flat_like: dict, where: str):
    keys, like_keys = set(manifest["keys"]), set(flat_like)
    problems = []
    missing = sorted(like_keys - keys)
    unexpected = sorted(keys - like_keys)
    if missing:
        problems.append(f"missing keys {missing}")
    if unexpected:
        problems.append(f"unexpected keys {unexpected}")
    for k in sorted(like_keys & keys):
        ref = flat_like[k]
        shape = tuple(manifest["shapes"][k])
        dtype = manifest["dtypes"][k]
        if shape != tuple(ref.shape):
            problems.append(f"{k}: shape {shape} != expected {tuple(ref.shape)}")
        if np.dtype(dtype) != np.dtype(ref.dtype):
            problems.append(f"{k}: dtype {dtype} != expected {np.dtype(ref.dtype)}")
    if problems:
        raise ValueError(
            f"checkpoint {where} does not match the restore target:\n  "
            + "\n  ".join(problems))


def restore(ckpt_dir: str, like: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like``; optionally device_put with
    per-leaf shardings (same treedef as ``like``).  Raises ``ValueError``
    if the checkpoint's manifest disagrees with ``like`` on keys, shapes,
    or dtypes."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat_like, treedef = _flatten(like)
    _validate(read_manifest(ckpt_dir, step), flat_like, d)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves = [data[key].astype(flat_like[key].dtype) for key in flat_like]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree
