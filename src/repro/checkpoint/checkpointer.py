"""Minimal sharding-aware checkpointer: npz payload + JSON manifest.

Saves a pytree of jax.Arrays as flattened npz entries keyed by tree path;
restores onto the caller-provided sharding (device_put per leaf).  No orbax
in this offline container — the format is deliberately trivial and
append-only (step-numbered directories + a LATEST pointer).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_SAFE.sub("_", str(getattr(p, "key", getattr(p, "idx", p))))
                       for p in path)
        out[key or "_root"] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree: PyTree, extra: Optional[dict] = None):
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(ckpt_dir, "LATEST"), "w") as f:
        f.write(str(step))
    return d


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, like: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``like``; optionally device_put with
    per-leaf shardings (same treedef as ``like``)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like, treedef = _flatten(like)
    leaves = []
    for key, ref in flat_like.items():
        arr = data[key]
        assert arr.shape == tuple(ref.shape), (key, arr.shape, ref.shape)
        leaves.append(arr.astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree
