"""Unified two-sided wire codecs (DESIGN.md §9).

Every compression operator in the repo is realized as a ``Codec``:

    encode(key, x)  -> WirePayload     (the pytree that actually hits a wire)
    decode(payload) -> x_hat           (the dequantized value, Assumption 5)

with the round-trip ``decode(encode(key, x))`` REQUIRED to be bitwise
identical to the legacy one-shot ``compress(key, x)`` for the operators that
predate this layer (global-norm squant, tile_squant, sparsify; pinned by
tests/test_codec.py).  The factoring that makes this possible for squant:
IEEE-754 multiplication by ``sign(x) in {-1, 0, +1}`` is exact and commutes,
so ``((sign * psi) * norm) / s == ((sign * norm) * psi) / s`` bit-for-bit —
the int8 levels carry ``sign * psi`` and the scale carries the norm.

One registry serves every layer:

  * ``core/compression.py``  — simulator ``Compressor`` objects are thin
                               round-trip wrappers over codecs;
  * ``core/artemis.py``      — dense + Pallas uplinks and the downlink
                               dispatch on codecs (``fused_uplink`` names the
                               kernel family a codec can ride);
  * ``core/dist.py``         — the bucketed/leaf mesh wires move
                               ``WirePayload`` pytrees around the ring
                               (``fused_acc`` marks payloads the fused
                               ``kernels/bucket_ring`` dequant-accumulate
                               understands);
  * ``core/faults.py``       — bit-flips / scrubbing act on the payload
                               representation uniformly (``validate`` is the
                               server's checksum);
  * ``launch/roofline.py``   — wire-byte models read ``wire_bytes(shape)``
                               instead of re-deriving analytic formulas.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

FP_BITS = 32  # uncompressed scalar width used by the paper's bit accounting


# ---------------------------------------------------------------------------
# WirePayload — the pytree that moves on a wire
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PayloadMeta:
    """Static (trace-time) payload metadata: which codec produced it, the
    original array shape/dtype to restore on decode, and the codec's static
    parameters.  Hashable — it rides in the pytree aux_data."""
    codec: str
    shape: Tuple[int, ...]
    dtype: str
    params: Tuple[Tuple[str, Any], ...] = ()

    def param(self, name: str, default=None):
        return dict(self.params).get(name, default)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WirePayload:
    """A named bundle of wire arrays (levels/indices/scales/values...) plus
    static metadata.  Registered as a pytree, so payloads vmap, scan, psum
    and ``ppermute`` like any other value; leaves flatten in sorted-key
    order (load-bearing: fault streams key off that order)."""
    data: Dict[str, jax.Array]
    meta: PayloadMeta

    def __getitem__(self, name: str) -> jax.Array:
        return self.data[name]

    def replace(self, **updates) -> "WirePayload":
        return WirePayload({**self.data, **updates}, self.meta)

    def tree_flatten(self):
        keys = tuple(sorted(self.data))
        return tuple(self.data[k] for k in keys), (keys, self.meta)

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, meta = aux
        return cls(data=dict(zip(keys, children)), meta=meta)


# ---------------------------------------------------------------------------
# Codec — the two-sided operator
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Codec:
    """A two-sided compression operator with known variance factor omega.

    ``bits`` is the paper-side Elias-coded metering (Prop. S1 — what the
    simulator charges); ``wire_bytes`` is the physical payload the mesh
    backend actually ships, split by HLO dtype so roofline models and the
    CI wire-format guard derive from the same source of truth.
    """
    name: str
    omega: float                        # Assumption-5 variance factor
    encode: Callable                    # (key, x) -> WirePayload
    decode: Callable                    # (WirePayload) -> x_hat
    bits: Callable                      # (n_elements,) -> float
    wire_bytes: Callable                # (shape,) -> {hlo_dtype: bytes}
    validate: Callable                  # (WirePayload) -> f32 scalar {0., 1.}
    unbiased: bool = True
    fused_uplink: Optional[str] = None  # kernel family for the fused
                                        # [N, d] artemis uplink (or None)
    fused_acc: bool = False             # kernels/bucket_ring understands
                                        # this payload's dequant-accumulate

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        """Round-trip compress: decode(encode(key, x))."""
        return self.decode(self.encode(key, x))

    def wire_bytes_total(self, shape) -> float:
        return float(sum(self.wire_bytes(shape).values()))


def _nelems(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _finite_nonneg(x: jax.Array) -> jax.Array:
    return jnp.all(jnp.isfinite(x) & (x >= 0))


# ---------------------------------------------------------------------------
# identity — omega = 0
# ---------------------------------------------------------------------------

def _identity_codec(d: int, **_) -> Codec:
    def encode(key, x):
        del key
        meta = PayloadMeta("identity", tuple(x.shape), str(x.dtype))
        return WirePayload({"values": x}, meta)

    def decode(p):
        return p["values"]

    def validate(p):
        return jnp.all(jnp.isfinite(p["values"])).astype(jnp.float32)

    return Codec(
        name="identity", omega=0.0, encode=encode, decode=decode,
        bits=lambda n: FP_BITS * n,
        wire_bytes=lambda shape: {"f32": 4 * _nelems(shape)},
        validate=validate)


# ---------------------------------------------------------------------------
# s-quantization (paper Definition 1 / QSGD) — global-norm scale
# ---------------------------------------------------------------------------

def squant_omega(d: int, s: int) -> float:
    """omega_C = min(d/s^2, sqrt(d)/s)  (Alistarh et al., App. A.1)."""
    return min(d / s**2, math.sqrt(d) / s)


def squant_bits(n: int, s: int) -> float:
    """Elias-coded message size upper bound (Prop. S1)."""
    t = s * (s + math.sqrt(n))
    return (3.0 + 1.5 * math.log(2.0 * (s**2 + n) / t)) * t + FP_BITS


def _squant_levels(key, x, s):
    """Stochastic level rounding shared by the squant family: int8 levels
    ``sign(x) * psi`` for rows normalized by ``norm`` (same uniforms, same
    comparisons as the legacy one-shot operators)."""
    norm = jnp.linalg.norm(x)
    r = jnp.where(norm > 0, jnp.abs(x) / norm * s, jnp.zeros_like(x))
    low = jnp.floor(r)
    u = jax.random.uniform(key, x.shape)
    psi = low + (u < (r - low)).astype(x.dtype)
    return (jnp.sign(x) * psi).astype(jnp.int8), norm


def _squant_codec(d: int, s: int = 1, **_) -> Codec:
    s = int(s)
    if not 1 <= s <= 126:
        raise ValueError(f"squant levels s={s} must fit int8: 1 <= s <= 126")

    def encode(key, x):
        flat = x.reshape(-1)
        q, norm = _squant_levels(key, flat, s)
        meta = PayloadMeta("squant", tuple(x.shape), str(x.dtype),
                           (("s", s),))
        # the scale is the UNdivided norm: decode does (q * norm) / s, which
        # is bitwise the legacy sign*norm*psi/s (sign flips commute exactly)
        return WirePayload({"levels": q, "scales": norm}, meta)

    def decode(p):
        dt = jnp.dtype(p.meta.dtype)
        out = p["levels"].astype(dt) * p["scales"].astype(dt) / s
        return out.reshape(p.meta.shape).astype(dt)

    def validate(p):
        okq = jnp.all(jnp.abs(p["levels"].astype(jnp.int32)) <= s + 1)
        return (okq & _finite_nonneg(p["scales"])).astype(jnp.float32)

    return Codec(
        name=f"squant(s={s})", omega=squant_omega(d, s),
        encode=encode, decode=decode,
        bits=lambda n, s=s: squant_bits(n, s),
        wire_bytes=lambda shape: {"s8": _nelems(shape), "f32": 4},
        validate=validate, fused_uplink="squant_rows")


# ---------------------------------------------------------------------------
# per-tile s-quantization (TPU-native adaptation; DESIGN.md §3)
# ---------------------------------------------------------------------------

def _tile_squant_codec(d: int, s: int = 1, tile: int = 1024, **_) -> Codec:
    s, tile = int(s), int(tile)
    if not 1 <= s <= 126:
        raise ValueError(f"tile_squant levels s={s} must fit int8")

    def encode(key, x):
        flat = x.reshape(-1)
        n = flat.shape[0]
        pad = (-n) % tile
        padded = jnp.pad(flat, (0, pad))
        tiles = padded.reshape(-1, tile)
        norms = jnp.linalg.norm(tiles, axis=1, keepdims=True)
        r = jnp.where(norms > 0, jnp.abs(tiles) / norms * s,
                      jnp.zeros_like(tiles))
        low = jnp.floor(r)
        u = jax.random.uniform(key, tiles.shape)
        psi = low + (u < (r - low)).astype(tiles.dtype)
        q = (jnp.sign(tiles) * psi).astype(jnp.int8)
        meta = PayloadMeta("tile_squant", tuple(x.shape), str(x.dtype),
                           (("s", s), ("tile", tile)))
        return WirePayload({"levels": q, "scales": norms}, meta)

    def decode(p):
        dt = jnp.dtype(p.meta.dtype)
        out = p["levels"].astype(dt) * p["scales"].astype(dt) / s
        n = _nelems(p.meta.shape)
        return out.reshape(-1)[:n].reshape(p.meta.shape).astype(dt)

    def validate(p):
        okq = jnp.all(jnp.abs(p["levels"].astype(jnp.int32)) <= s + 1)
        return (okq & _finite_nonneg(p["scales"])).astype(jnp.float32)

    def wire_bytes(shape, tile=tile):
        n = _nelems(shape)
        t = -(-n // tile)
        return {"s8": t * tile, "f32": 4 * t}

    return Codec(
        name=f"tile_squant(s={s},t={tile})", omega=squant_omega(tile, s),
        encode=encode, decode=decode,
        bits=lambda n, s=s, tile=tile: math.ceil(n / tile)
        * squant_bits(min(n, tile), s),
        wire_bytes=wire_bytes, validate=validate)


# ---------------------------------------------------------------------------
# row s-quantization — the mesh wire format (core/dist.py, kernels/*)
# ---------------------------------------------------------------------------

def row_squant_encode(key: jax.Array, x: jax.Array, s: int):
    """Per-row (last axis) stochastic s-quantization -> (levels int8,
    scales f32 = norm/s, keepdims).  Row-wise scales keep every op
    elementwise or a last-axis reduction, so GSPMD shards it without data
    movement beyond a tiny partial-norm reduce.  This IS the wire format of
    ``kernels/squant.py`` / ``kernels/fused_memory.py`` (decode is
    ``q * scale``, the division by s is folded into the scale)."""
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        norm = jnp.abs(xf)
    else:
        norm = jnp.sqrt(jnp.sum(jnp.square(xf), axis=-1, keepdims=True))
    # an all-NaN/Inf row must not ship a NaN scale: clamp to 0 so decode is
    # exactly 0 (finite) whatever the levels hold (matches kernels/squant.py)
    scale = jnp.where(jnp.isfinite(norm), norm / s, 0.0)
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(xf) / safe * s
    low = jnp.floor(r)
    u = jax.random.uniform(key, x.shape, jnp.float32)
    psi = low + (u < (r - low)).astype(jnp.float32)
    q = (jnp.sign(xf) * psi).astype(jnp.int8)
    return q, scale


def row_squant_decode(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _row_squant_codec(d: int, s: int = 1, **_) -> Codec:
    s = int(s)
    if not 1 <= s <= 126:
        raise ValueError(f"row_squant levels s={s} must fit int8")

    def encode(key, x):
        q, scale = row_squant_encode(key, x, s)
        meta = PayloadMeta("row_squant", tuple(x.shape), str(x.dtype),
                           (("s", s),))
        return WirePayload({"levels": q, "scales": scale}, meta)

    def decode(p):
        return row_squant_decode(p["levels"], p["scales"],
                                 jnp.dtype(p.meta.dtype))

    def validate(p):
        okq = jnp.all(jnp.abs(p["levels"].astype(jnp.int32)) <= s + 1)
        return (okq & _finite_nonneg(p["scales"])).astype(jnp.float32)

    def wire_bytes(shape):
        n = _nelems(shape)
        rows = _nelems(shape[:-1]) if len(shape) else 1
        return {"s8": n, "f32": 4 * rows}

    return Codec(
        name=f"row_squant(s={s})", omega=squant_omega(max(d, 1), s),
        encode=encode, decode=decode,
        bits=lambda n, s=s, d=max(d, 1): math.ceil(n / d)
        * squant_bits(min(n, d), s),
        wire_bytes=wire_bytes, validate=validate,
        fused_uplink="squant_rows", fused_acc=True)


# ---------------------------------------------------------------------------
# stochastic sparsification (Wen et al. 2017) — index+value payload
# ---------------------------------------------------------------------------

def _sparsify_codec(d: int, q: float = 0.25, **_) -> Codec:
    q = float(q)
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sparsify keep-probability q={q} not in (0, 1]")

    def encode(key, x):
        flat = x.reshape(-1)
        n = flat.shape[0]
        mask = jax.random.bernoulli(key, q, x.shape).reshape(-1)
        # stable survivor-first ordering: indices of kept coords ascending,
        # dropped slots filled with the out-of-range sentinel n (decode
        # scatters with mode="drop", so sentinels vanish)
        order = jnp.argsort(~mask, stable=True)
        kept = mask[order]
        idx = jnp.where(kept, order, n).astype(jnp.int32)
        vals = jnp.where(kept, flat[order] / q, 0.0).astype(flat.dtype)
        meta = PayloadMeta("sparsify", tuple(x.shape), str(x.dtype),
                           (("q", q),))
        return WirePayload({"indices": idx, "values": vals}, meta)

    def decode(p):
        n = _nelems(p.meta.shape)
        dt = jnp.dtype(p.meta.dtype)
        flat = jnp.zeros((n,), dt).at[p["indices"]].set(
            p["values"].astype(dt), mode="drop")
        return flat.reshape(p.meta.shape)

    def validate(p):
        n = _nelems(p.meta.shape)
        oki = jnp.all((p["indices"] >= 0) & (p["indices"] <= n))
        return (oki & jnp.all(jnp.isfinite(p["values"]))).astype(jnp.float32)

    def wire_bytes(shape):
        # fixed-capacity payload: n index slots (s32) + n value slots (f32)
        n = _nelems(shape)
        return {"s32": 4 * n, "f32": 4 * n}

    return Codec(
        name=f"sparsify(q={q})", omega=1.0 / q - 1.0,
        encode=encode, decode=decode,
        bits=lambda n, q=q: q * n * (FP_BITS + max(1.0, math.log2(max(n, 2)))),
        wire_bytes=wire_bytes, validate=validate)


# ---------------------------------------------------------------------------
# top-k (biased contrast baseline; violates Assumption 5 unbiasedness)
# ---------------------------------------------------------------------------

def _topk_codec(d: int, frac: float = 0.1, **_) -> Codec:
    frac = float(frac)

    def encode(key, x):
        del key
        flat = x.reshape(-1)
        n = flat.shape[0]
        k = max(1, int(n * frac))
        # exact k coordinates even on tied magnitudes — the old
        # sort-threshold + >= kept every tied coord, so the bit accounting
        # undercharged the message actually sent
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = flat[idx]
        meta = PayloadMeta("topk", tuple(x.shape), str(x.dtype),
                           (("frac", frac), ("k", k)))
        return WirePayload({"indices": idx.astype(jnp.int32), "values": vals},
                           meta)

    def decode(p):
        n = _nelems(p.meta.shape)
        dt = jnp.dtype(p.meta.dtype)
        flat = jnp.zeros((n,), dt).at[p["indices"]].set(
            p["values"].astype(dt), mode="drop")
        return flat.reshape(p.meta.shape)

    def validate(p):
        n = _nelems(p.meta.shape)
        oki = jnp.all((p["indices"] >= 0) & (p["indices"] < n))
        return (oki & jnp.all(jnp.isfinite(p["values"]))).astype(jnp.float32)

    def wire_bytes(shape, frac=frac):
        n = _nelems(shape)
        k = max(1, int(n * frac))
        return {"s32": 4 * k, "f32": 4 * k}

    return Codec(
        name=f"topk({frac})", omega=1.0 - frac,
        encode=encode, decode=decode,
        bits=lambda n, frac=frac: max(1, int(n * frac))
        * (FP_BITS + max(1.0, math.log2(max(n, 2)))),
        wire_bytes=wire_bytes, validate=validate, unbiased=False)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., Codec]] = {
    "identity": _identity_codec,
    "none": _identity_codec,
    "squant": _squant_codec,
    "tile_squant": _tile_squant_codec,
    "row_squant": _row_squant_codec,
    "sparsify": _sparsify_codec,
    "topk": _topk_codec,
}


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_codec(name: str, d: int, **kwargs) -> Codec:
    """Build a registered codec for messages of flattened dimension ``d``
    (``d`` fixes omega; encode adapts to whatever shape it is handed).
    Unknown static kwargs are ignored, matching the legacy compressor
    factories (variant tables pass a shared kwargs dict)."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown codec {name!r}; choose from {sorted(_REGISTRY)}")
    return _REGISTRY[name](d, **kwargs)
