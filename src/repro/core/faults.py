"""Deterministic fault model for the Artemis stack (DESIGN.md §8).

The paper assumes workers either participate cleanly or not at all
(Assumption 6: i.i.d. Bernoulli device sampling) and that every payload that
reaches the server is the payload that was sent.  Real heterogeneous fleets
break both: availability is *correlated* over rounds (a phone that just went
offline tends to stay offline), slow devices miss the round deadline, wire
payloads arrive corrupted, and a worker's local step occasionally blows up
to NaN/Inf.  ``FaultConfig`` makes all of that a **PRNG-driven, fully traced
config** that composes into ``ArtemisConfig`` (sweep engine cells) and
``DistConfig`` (mesh backend, both wires), so whole fault grids compile into
one program exactly like the fault-free grids do.

Fault taxonomy (all rates are per-round):

  * stragglers       — ``straggler_rate``: an otherwise-available worker
                       misses the round deadline and is dropped (uplink never
                       arrives; it pays nothing, downloads catch-up later).
  * correlated
    participation    — ``p_stay``: the {0,1} availability of each worker is a
                       two-state Markov chain with ``P(1->1) = p_stay`` and
                       ``P(0->1)`` chosen so the stationary distribution stays
                       ``p`` (the config's participation probability).  With
                       ``p_stay = p`` both transition rows equal ``p`` and the
                       chain IS the paper's i.i.d. Bernoulli mask — bit-for-bit,
                       because the same uniform is compared to the same
                       threshold.  Lag-1 autocorrelation is
                       ``(p_stay - p) / (1 - p)``.
  * wire bit-flips   — ``bitflip_rate``: each element of a transmitted payload
                       has an independent chance of one random flipped bit
                       (int8 levels XOR a random bit; f32 scales XOR a random
                       bit of the IEEE pattern).  Only payloads that were
                       actually sent (active workers) can be corrupted.
  * gradient blowups — ``blowup_rate``: a worker's whole stochastic gradient
                       is replaced by ``blowup_value`` (default NaN; set a
                       large finite value like 1e30 to exercise the divergence
                       sentinel instead of the finite-scrubber).

Server-side defenses (the "self-healing" half):

  * ``scrub``        — finite/checksum scrubbing: a payload whose scales are
                       non-finite/negative or whose int8 levels exceed the
                       quantizer range ``s`` is *treated as inactive* by
                       zeroing its wire scales — exactly the PP2
                       ``scale *= active`` mechanism, so h/hbar/e are left
                       untouched and the round's algebra is that of a round
                       the worker sat out.  Non-finite *gradients* are caught
                       at entry the same way (worker masked inactive).
  * ``sentinel``     — divergence sentinel (sweep engine): when the monitored
                       loss or ``||w||`` exceeds ``sentinel`` (or goes
                       non-finite), the carry is rolled back to the last good
                       evaluation snapshot and the step size is scaled by
                       ``backoff`` (geometric), all in-trace.

``FaultConfig()`` (all rates zero, defenses off) is the identity: every code
path is statically gated on the config, so a zero-fault config produces the
byte-identical trace — and therefore byte-identical trajectories — as no
config at all.  This is pinned by tests/test_faults.py on the sweep engine
and on both mesh wires.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# folded into round keys to derive fault-injection streams that never collide
# with the uplink/downlink/participation streams
FAULT_SALT = 0x6F175EED


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """PRNG-driven fault injection + server-side defense switches.

    All fields are static at trace time; a grid of FaultConfigs sweeps
    through ``core.sweep.run_sweep`` like any other config axis.
    """
    straggler_rate: float = 0.0     # P(available worker misses the deadline)
    p_stay: Optional[float] = None  # Markov P(active -> active); None = i.i.d.
    bitflip_rate: float = 0.0       # per-element P(one random flipped bit)
    blowup_rate: float = 0.0        # per-worker P(gradient -> blowup_value)
    blowup_value: float = float("nan")  # NaN, or large finite for sentinel
    scrub: bool = False             # server finite/checksum scrubbing
    sentinel: float = 0.0           # loss/||w|| rollback threshold (0 = off)
    backoff: float = 0.5            # gamma *= backoff on each rollback

    def __post_init__(self):
        for name in ("straggler_rate", "bitflip_rate", "blowup_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} not in [0, 1]")
        if self.p_stay is not None and not 0.0 <= self.p_stay <= 1.0:
            raise ValueError(f"p_stay={self.p_stay} not in [0, 1]")
        if not 0.0 < self.backoff <= 1.0:
            raise ValueError(f"backoff={self.backoff} not in (0, 1]")

    # ---- static gates (evaluated at trace time) ---------------------------

    @property
    def markov(self) -> bool:
        return self.p_stay is not None

    @property
    def rollback(self) -> bool:
        return self.sentinel > 0.0

    @property
    def wire_faults(self) -> bool:
        """Anything that touches the uplink payload path."""
        return self.bitflip_rate > 0.0 or self.scrub

    @property
    def enabled(self) -> bool:
        return (self.straggler_rate > 0.0 or self.markov
                or self.bitflip_rate > 0.0 or self.blowup_rate > 0.0
                or self.scrub or self.rollback)


ZERO = FaultConfig()


def of(fc: Optional[FaultConfig]) -> FaultConfig:
    """None-safe accessor: configs default to ``faults=None`` == all-off."""
    return ZERO if fc is None else fc


# ---------------------------------------------------------------------------
# correlated (Markov) participation
# ---------------------------------------------------------------------------

def markov_rates(fc: FaultConfig, p: float) -> Tuple[float, float]:
    """Transition probabilities (a, b) = (P(1->1), P(0->1)) with stationary
    participation ``p``.  ``p_stay = p`` gives a == b == p (i.i.d.)."""
    a = float(fc.p_stay)
    if p >= 1.0:
        return a, 1.0
    b = p * (1.0 - a) / (1.0 - p)
    if b > 1.0 + 1e-9:
        raise ValueError(
            f"Markov participation infeasible: p={p}, p_stay={a} needs "
            f"P(0->1)={b:.3f} > 1; require p_stay >= (2p-1)/p")
    return a, min(b, 1.0)


def markov_autocorr(fc: FaultConfig, p: float) -> float:
    """Lag-1 autocorrelation of the stationary availability chain."""
    if p >= 1.0:
        return 0.0
    return (float(fc.p_stay) - p) / (1.0 - p)


def participation(fc: FaultConfig, p: float, u: jax.Array, prev: jax.Array,
                  k: jax.Array) -> jax.Array:
    """Availability mask from uniforms ``u`` (same stream the i.i.d. mask
    uses).  ``prev``: previous-round availability (same shape as ``u``);
    ``k``: round index (round 0 draws from the stationary distribution).
    Reduces bitwise to ``u < p`` when the chain is off or ``p_stay == p``.
    """
    if not fc.markov:
        return (u < p).astype(jnp.float32)
    a, b = markov_rates(fc, p)
    thresh = jnp.where(k == 0, p, jnp.where(prev > 0, a, b))
    return (u < thresh).astype(jnp.float32)


# ---------------------------------------------------------------------------
# injection primitives
# ---------------------------------------------------------------------------

def corrupt_int8(key: jax.Array, q: jax.Array, rate: float) -> jax.Array:
    """Flip one random bit of each int8 element with probability ``rate``."""
    kb, km = jax.random.split(key)
    bit = jax.random.randint(kb, q.shape, 0, 8, dtype=jnp.int32)
    hit = jax.random.bernoulli(km, rate, q.shape)
    mask = jnp.left_shift(jnp.uint8(1), bit.astype(jnp.uint8))
    flipped = jax.lax.bitcast_convert_type(
        jnp.bitwise_xor(jax.lax.bitcast_convert_type(q, jnp.uint8), mask),
        jnp.int8)
    return jnp.where(hit, flipped, q)


def corrupt_f32(key: jax.Array, x: jax.Array, rate: float) -> jax.Array:
    """Flip one random bit of each f32 element's IEEE-754 pattern with
    probability ``rate`` (exponent-bit flips are how NaN/Inf/huge values
    arrive off a real wire)."""
    kb, km = jax.random.split(key)
    bit = jax.random.randint(kb, x.shape, 0, 32, dtype=jnp.int32)
    hit = jax.random.bernoulli(km, rate, x.shape)
    pattern = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    flipped = jax.lax.bitcast_convert_type(
        jnp.bitwise_xor(pattern, jnp.left_shift(jnp.int32(1), bit)),
        jnp.float32)
    return jnp.where(hit, flipped, x.astype(jnp.float32))


def corrupt_i32(key: jax.Array, x: jax.Array, rate: float) -> jax.Array:
    """Flip one random bit of each int32 element (index payloads) with
    probability ``rate``."""
    kb, km = jax.random.split(key)
    bit = jax.random.randint(kb, x.shape, 0, 32, dtype=jnp.int32)
    hit = jax.random.bernoulli(km, rate, x.shape)
    flipped = jnp.bitwise_xor(x, jnp.left_shift(jnp.int32(1), bit))
    return jnp.where(hit, flipped, x)


# ---------------------------------------------------------------------------
# payload-level fault operators (codec WirePayloads — or any pytree)
# ---------------------------------------------------------------------------

def _lead_broadcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Broadcast a leading-axes mask against a payload leaf (e.g. a [N]
    per-worker mask against [N, d] levels and [N] scales)."""
    mask = jnp.asarray(mask)
    extra = leaf.ndim - mask.ndim
    if extra > 0:
        mask = mask.reshape(mask.shape + (1,) * extra)
    return mask


def corrupt_payload(key: jax.Array, payload, rate: float,
                    only: Optional[jax.Array] = None):
    """Flip bits of every wire leaf of ``payload`` uniformly, dispatching on
    the leaf dtype (int8 levels, f32 scales/values, int32 indices).  Leaves
    corrupt in sorted-key flatten order with keys split off ``key`` — for
    the classic {levels, scales} payload this reproduces the pre-codec
    ``kq, ks = split(key)`` streams bit-for-bit.  ``only``: optional {0,1}
    mask (broadcast on leading axes) restricting corruption to payloads that
    were actually sent."""
    leaves, treedef = jax.tree.flatten(payload)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        if leaf.dtype == jnp.int8:
            c = corrupt_int8(k, leaf, rate)
        elif leaf.dtype == jnp.int32:
            c = corrupt_i32(k, leaf, rate)
        elif jnp.issubdtype(leaf.dtype, jnp.floating):
            c = corrupt_f32(k, leaf, rate).astype(leaf.dtype)
        else:
            c = leaf
        if only is not None:
            c = jnp.where(_lead_broadcast(only, leaf) > 0, c, leaf)
        out.append(c)
    return jax.tree.unflatten(treedef, out)


def mask_payload(payload, keep: jax.Array):
    """PP2 inactivity on the payload representation: scale every FLOAT wire
    leaf by ``keep`` so a masked payload decodes to exactly zero, while the
    integer levels/indices ride along untouched (exactly the legacy
    ``scale *= active`` mechanism, generalized).  No NaN cleanup here — an
    unprotected corrupt payload must keep poisoning downstream arithmetic."""
    def one(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf * _lead_broadcast(keep, leaf).astype(leaf.dtype)
        return leaf
    return jax.tree.map(one, payload)


def scrub_payload(payload, valid: jax.Array):
    """Server-side scrubbing: zero the non-finite float entries AND scale by
    the ``valid`` checksum mask (``codec.Codec.validate``), so a corrupt
    payload contributes exactly zero through the same zero-scale path PP2
    uses for inactive workers."""
    def one(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return nan_to_zero(leaf) * _lead_broadcast(valid, leaf
                                                       ).astype(leaf.dtype)
        return leaf
    return jax.tree.map(one, payload)


def blowup_mask(fc: FaultConfig, key: jax.Array, n: int) -> jax.Array:
    """The per-worker blowup draw ([N] bool at rate ``blowup_rate``), split
    out of :func:`inject_blowup` so telemetry can count hits off the SAME
    Bernoulli sample that corrupts the gradients (same key, same draw —
    counting is bitwise-invisible to the fault stream)."""
    return jax.random.bernoulli(key, fc.blowup_rate, (n,))


def apply_blowup(fc: FaultConfig, hit: jax.Array, grads: jax.Array
                 ) -> jax.Array:
    """Replace the masked per-worker gradients ([N, ...]; axis 0 = workers)
    with ``blowup_value``."""
    n = grads.shape[0]
    hit = hit.reshape((n,) + (1,) * (grads.ndim - 1))
    return jnp.where(hit, jnp.float32(fc.blowup_value).astype(grads.dtype),
                     grads)


def inject_blowup(fc: FaultConfig, key: jax.Array, grads: jax.Array,
                  ) -> jax.Array:
    """Replace whole per-worker gradients ([N, ...]; axis 0 = workers) with
    ``blowup_value`` at rate ``blowup_rate``."""
    return apply_blowup(fc, blowup_mask(fc, key, grads.shape[0]), grads)


# ---------------------------------------------------------------------------
# server-side scrubbing
# ---------------------------------------------------------------------------

def finite_mask(x: jax.Array, axes) -> jax.Array:
    """1.0 where ``x`` is finite over ``axes`` (keepdims), else 0.0."""
    return jnp.all(jnp.isfinite(x), axis=axes, keepdims=True
                   ).astype(jnp.float32)


def nan_to_zero(x: jax.Array) -> jax.Array:
    """Zero the non-finite entries so they cannot poison masked arithmetic
    (``0 * NaN`` is NaN — masking alone is not enough)."""
    return jnp.where(jnp.isfinite(x), x, jnp.zeros_like(x))


def payload_valid(q: jax.Array, scale: jax.Array, lmax: int, axes
                  ) -> jax.Array:
    """Checksum-style validity of a quantized payload, reduced over ``axes``
    (keepdims): int8 levels must lie in the legal quantizer range
    ``[-lmax, lmax]`` (for s-quantization ``lmax = s + 1``) and scales must
    be finite and non-negative.  The caller multiplies the wire scales by
    this mask — the corrupt payload then contributes *exactly* zero through
    the same ``scale *= active`` path PP2 uses for inactive workers, so
    h/hbar/e stay untouched."""
    okq = jnp.all(jnp.abs(q.astype(jnp.int32)) <= lmax, axis=axes,
                  keepdims=True)
    oks = jnp.all(jnp.isfinite(scale) & (scale >= 0), axis=axes,
                  keepdims=True)
    return (okq & oks).astype(scale.dtype)
