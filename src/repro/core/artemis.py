"""Artemis (paper Algorithm 1) as a functional JAX module.

One round maps stacked per-worker stochastic gradients ``g: [N, d]`` to the
descent direction ``Omega: [d]`` plus the next algorithm state.  All six
framework variants are obtained from the same code path:

    variant     C_up        C_dwn      memory(alpha)
    sgd         identity    identity   0
    qsgd        squant      identity   0
    diana       squant      identity   >0
    biqsgd      squant      squant     0
    artemis     squant      squant     >0
    sgd-mem     identity    identity   >0      (PP2 benchmark of Fig. 6)

Partial participation: ``active`` is a {0,1} mask of shape [N].
 * PP1 — server holds per-worker memories; ghat = mean_S(Delta_hat_i + h_i)/p.
 * PP2 — server holds ONE memory hbar reused for inactive workers (novel algo):
         ghat = hbar + (1/(pN)) sum_S Delta_hat_i ;  hbar += (alpha/N) sum_S Delta_hat_i.

Error feedback (beyond paper, Dore-style) is available via ``error_feedback=True``.

Both uplinks and the downlink dispatch on registered ``core/codec.py``
codecs: the dense path vmaps the codec round-trip (any registered operator —
sparsify, topk, tile_squant...), while ``backend="pallas"`` rides the fused
kernels for codecs that declare the matching ``fused_uplink`` family and
falls back to the dense path for the rest (no more hard-fails on
``cfg.up != "squant"``; EF is supported on both).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import codec as wire
from repro.core import compression as comp
from repro.core import faults

BACKENDS = ("dense", "pallas")


@dataclasses.dataclass(frozen=True)
class ArtemisConfig:
    dim: int
    n_workers: int
    up: str = "squant"            # uplink codec name (core/codec.py registry)
    dwn: str = "squant"           # downlink codec name
    up_kwargs: dict = dataclasses.field(default_factory=dict)
    dwn_kwargs: dict = dataclasses.field(default_factory=dict)
    alpha: Optional[float] = None  # memory rate; None -> 1/(2(omega_up+1)); 0 disables
    p: float = 1.0                 # participation probability (Assumption 6)
    pp_mode: str = "pp2"           # 'pp1' | 'pp2'
    error_feedback: bool = False   # Dore-like EF (beyond paper)
    backend: str = "dense"         # 'dense' | 'pallas' (fused uplink kernels)
    faults: Optional[faults.FaultConfig] = None  # fault injection + defenses

    def codecs(self) -> Tuple[wire.Codec, wire.Codec]:
        # kwargs may be a dict or a (hashable) tuple of (key, value) pairs
        c_up = wire.make_codec(self.up, self.dim, **dict(self.up_kwargs))
        c_dwn = wire.make_codec(self.dwn, self.dim, **dict(self.dwn_kwargs))
        return c_up, c_dwn

    def compressors(self) -> Tuple[comp.Compressor, comp.Compressor]:
        c_up, c_dwn = self.codecs()
        return comp.from_codec(c_up), comp.from_codec(c_dwn)

    def resolved_alpha(self) -> float:
        if self.alpha is not None:
            return float(self.alpha)
        c_up, _ = self.codecs()
        if c_up.omega == 0.0:
            return 0.0   # no uplink compression -> memory unnecessary by default
        return 1.0 / (2.0 * (c_up.omega + 1.0))


class ArtemisState(NamedTuple):
    h: jax.Array        # [N, d] per-worker memories (zeros when alpha == 0)
    hbar: jax.Array     # [d] server memory  (PP2; == mean(h) under full participation)
    e: jax.Array        # [N, d] error-feedback buffers (zeros unless enabled)
    step: jax.Array     # scalar int32


def init_state(cfg: ArtemisConfig, dtype=jnp.float32) -> ArtemisState:
    n, d = cfg.n_workers, cfg.dim
    return ArtemisState(
        h=jnp.zeros((n, d), dtype),
        hbar=jnp.zeros((d,), dtype),
        e=jnp.zeros((n, d), dtype),
        step=jnp.zeros((), jnp.int32),
    )


def variant_config(variant: str, dim: int, n_workers: int, s: int = 1,
                   p: float = 1.0, pp_mode: str = "pp2",
                   alpha: Optional[float] = None) -> ArtemisConfig:
    """Build the config for one of the named paper variants."""
    table = {
        "sgd":      dict(up="identity", dwn="identity", alpha=0.0),
        "qsgd":     dict(up="squant", dwn="identity", alpha=0.0),
        "diana":    dict(up="squant", dwn="identity", alpha=alpha),
        "biqsgd":   dict(up="squant", dwn="squant", alpha=0.0),
        "artemis":  dict(up="squant", dwn="squant", alpha=alpha),
        "sgd-mem":  dict(up="identity", dwn="identity", alpha=alpha if alpha is not None else 0.5),
        "dore":     dict(up="squant", dwn="squant", alpha=alpha, error_feedback=True),
    }
    if variant not in table:
        raise ValueError(f"unknown variant {variant!r}; choose from {sorted(table)}")
    kw = table[variant]
    return ArtemisConfig(dim=dim, n_workers=n_workers, p=p, pp_mode=pp_mode,
                         up_kwargs={"s": s}, dwn_kwargs={"s": s}, **kw)


def _uplink_dense(cfg: ArtemisConfig, c_up: wire.Codec, state: ArtemisState,
                  grads: jax.Array, up_keys: jax.Array, active: jax.Array,
                  alpha: float, fc: faults.FaultConfig, flt_key):
    """Reference uplink: vmap the codec round-trip over workers.  Works for
    EVERY registered codec — the faulted wire corrupts and validates the
    payload representation itself (levels/indices/scales), not the decoded
    value, so an index bit-flip on a sparsify payload is as real as a scale
    flip on squant."""
    delta = grads - state.h                                # [N,d]
    if cfg.error_feedback:
        delta = delta + state.e
    if not fc.wire_faults:
        delta_hat = jax.vmap(c_up)(up_keys, delta)         # [N,d]
        if cfg.error_feedback:
            new_e = state.e + (grads - state.h) - delta_hat
            new_e = active * new_e + (1 - active) * state.e
        else:
            new_e = state.e
        # only active workers compress/communicate & update their local memory
        delta_hat = active * delta_hat
        new_h = state.h + alpha * delta_hat                # inactive rows unchanged
        sum_hat = jnp.sum(delta_hat, axis=0)               # [d]
        return delta_hat, new_h, new_e, sum_hat, jnp.float32(0.0)
    # --- faulted wire: only sent (active) payloads can be corrupted --------
    payload = jax.vmap(c_up.encode)(up_keys, delta)        # leaves: [N, ...]
    if fc.bitflip_rate > 0.0:
        payload = faults.corrupt_payload(flt_key, payload, fc.bitflip_rate,
                                         only=active[:, 0])
    ok = active
    if fc.scrub:
        # failed checksum => treat the worker as inactive this round
        valid = jax.vmap(c_up.validate)(payload)           # [N]
        ok = active * valid[:, None]
        payload = faults.scrub_payload(payload, valid)
    sent = jax.vmap(c_up.decode)(payload)
    sent = faults.nan_to_zero(sent) * ok if fc.scrub else sent * active
    if cfg.error_feedback:
        new_e = state.e + (grads - state.h) - sent
        new_e = ok * new_e + (1 - ok) * state.e
    else:
        new_e = state.e
    # the fault model corrupts the encoder's output buffer, so the worker
    # memory tracks exactly what the server accepted (scrubbed rows: nothing)
    new_h = state.h + alpha * sent
    sum_hat = jnp.sum(sent, axis=0)
    scrubbed = jnp.sum(active) - jnp.sum(ok)
    return sent, new_h, new_e, sum_hat, scrubbed


def _uplink_pallas(cfg: ArtemisConfig, c_up: wire.Codec, state: ArtemisState,
                   grads: jax.Array, up_keys: jax.Array, active: jax.Array,
                   alpha: float, fc: faults.FaultConfig, flt_key):
    """Fused uplink for codecs of the ``squant_rows`` family: worker encode +
    memory update in one HBM pass (kernels/fused_memory.py) and server
    dequant-accumulate (kernels/ring_sum).

    Each worker row is one kernel block, so the per-block scale is the
    per-worker global L2 norm — identical semantics to ``squant`` on the
    dense path (same keys, same uniforms, same levels up to fp reassociation).
    Error feedback folds in by encoding ``g + e - h`` instead of ``g - h``
    (the EF buffer update happens outside the kernel).
    """
    from repro.kernels import default_interpret
    from repro.kernels.fused_memory import fused_memory_update
    from repro.kernels.ring_sum import ring_sum

    n, d = cfg.n_workers, cfg.dim
    s = int(cfg.up_kwargs.get("s", 1))
    itp = default_interpret()
    g_in = grads + state.e if cfg.error_feedback else grads
    # same uniforms the dense compressor would draw under vmap
    u = jax.vmap(lambda k: jax.random.uniform(k, (d,)))(up_keys)
    q, scales, h_fused = fused_memory_update(
        g_in, state.h, u, alpha, s=s, block=(1, d), interpret=itp)
    if not fc.wire_faults:
        # inactive workers neither transmit nor touch their memory
        new_h = active * h_fused + (1 - active) * state.h
        if cfg.error_feedback:
            delta_full = q.astype(grads.dtype) * scales     # unmasked decode
            new_e = state.e + (grads - state.h) - delta_full
            new_e = active * new_e + (1 - active) * state.e
        else:
            new_e = state.e
        act_scales = scales * active                        # [N,1]
        sum_hat = ring_sum(q[:, None, :], act_scales[:, :, None],
                           block=(1, d), interpret=itp).reshape(d)
        delta_hat = q.astype(grads.dtype) * act_scales      # [N,d] decoded
        return delta_hat, new_h, new_e, sum_hat, jnp.float32(0.0)
    # --- faulted wire: the kernel's payload is a row_squant WirePayload ----
    # (scale = norm/s; decode is q * scale), so the generic payload fault
    # operators and validate apply unchanged
    wc = wire.make_codec("row_squant", d, s=s)
    payload = wire.WirePayload(
        {"levels": q, "scales": scales},
        wire.PayloadMeta("row_squant", (n, d), str(grads.dtype), (("s", s),)))
    if fc.bitflip_rate > 0.0:
        payload = faults.corrupt_payload(flt_key, payload, fc.bitflip_rate,
                                         only=active[:, 0])
    ok = active
    if fc.scrub:
        valid = jax.vmap(wc.validate)(payload)              # [N]
        ok = active * valid[:, None]
        payload = faults.scrub_payload(payload, valid)
    q, scales = payload["levels"], payload["scales"]
    act_scales = scales * ok                                # [N,1]
    sum_hat = ring_sum(q[:, None, :], act_scales[:, :, None],
                       block=(1, d), interpret=itp).reshape(d)
    delta_hat = q.astype(grads.dtype) * act_scales          # [N,d] decoded
    if cfg.error_feedback:
        new_e = state.e + (grads - state.h) - delta_hat
        new_e = ok * new_e + (1 - ok) * state.e
    else:
        new_e = state.e
    # worker memory tracks the accepted payload (see _uplink_dense)
    new_h = state.h + alpha * delta_hat
    scrubbed = jnp.sum(active) - jnp.sum(ok)
    return delta_hat, new_h, new_e, sum_hat, scrubbed


def artemis_round(cfg: ArtemisConfig, state: ArtemisState, grads: jax.Array,
                  key: jax.Array, active: Optional[jax.Array] = None,
                  backend: Optional[str] = None):
    """One communication round.

    Args:
      grads:  [N, d] per-worker stochastic gradients g_{k+1}^i(w_k).
      active: optional {0,1} float mask [N]; default all-active.
      backend: 'dense' (reference) or 'pallas' (fused uplink kernels for
        codecs that declare the matching ``fused_uplink`` family; others
        fall back to the dense path); default ``cfg.backend``.

    Returns:
      omega:  [d] the (doubly) compressed descent direction Omega_{k+1}.
      state':  updated ArtemisState.
      stats:  dict of bit costs and diagnostics for this round.
    """
    c_up, c_dwn = cfg.codecs()
    alpha = cfg.resolved_alpha()
    n, d = cfg.n_workers, cfg.dim
    backend = cfg.backend if backend is None else backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")
    if active is None:
        active = jnp.ones((n,), grads.dtype)
    active = active.astype(grads.dtype)[:, None]          # [N,1]

    up_key, dwn_key = jax.random.split(jax.random.fold_in(key, state.step))
    up_keys = jax.random.split(up_key, n)
    fc = faults.of(cfg.faults)
    # fault stream branches off the round key via a salt so the base
    # up/dwn draws are untouched (zero-fault => byte-identical trace)
    flt_key = (jax.random.fold_in(jax.random.fold_in(key, state.step),
                                  faults.FAULT_SALT)
               if fc.wire_faults else None)

    # ---- workers: compress gradient differences ---------------------------
    use_fused = backend == "pallas" and c_up.fused_uplink == "squant_rows"
    uplink = _uplink_pallas if use_fused else _uplink_dense
    delta_hat, new_h, new_e, sum_hat, scrubbed = uplink(
        cfg, c_up, state, grads, up_keys, active, alpha, fc, flt_key)

    # ---- server: reconstruct, aggregate, compress downlink ----------------
    if cfg.pp_mode == "pp2":
        ghat = state.hbar + sum_hat / (cfg.p * n)
        new_hbar = state.hbar + alpha * sum_hat / n
    elif cfg.pp_mode == "pp1":
        # server-side copies of h_i; only ACTIVE memories are read
        ghat = sum_hat / (cfg.p * n) + jnp.sum(active * state.h, axis=0) / (cfg.p * n)
        new_hbar = jnp.mean(new_h, axis=0)
    else:
        raise ValueError(f"unknown pp_mode {cfg.pp_mode!r}")

    omega = c_dwn(dwn_key, ghat)

    delta = grads - state.h
    if cfg.error_feedback:
        delta = delta + state.e
    n_active = jnp.sum(active)
    # Metering rule (see DESIGN.md §4 / federated.run): the broadcast reaches
    # only the participating workers; returners' catch-up is metered by the
    # simulator on top of this.
    stats = {
        "uplink_bits": n_active * c_up.bits(d),
        "dwnlink_bits": n_active * c_dwn.bits(d),
        "compress_err_up": jnp.mean(jnp.sum((delta_hat - active * delta) ** 2, -1)),
        "compress_err_dwn": jnp.sum((omega - ghat) ** 2),
        "ghat_norm": jnp.linalg.norm(ghat),
        "wire_scrubbed": scrubbed,   # payloads dropped by the server this round
    }
    return omega, ArtemisState(new_h, new_hbar, new_e, state.step + 1), stats
