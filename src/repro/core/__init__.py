from repro.core import artemis, compression, federated  # noqa: F401
