"""Batched sweep engine: the whole {variant} x {gamma} x {seed} grid in ONE
compiled program.

The paper's experiment grids (§5, Figs. 2-6) are dozens of cells; running
them through ``federated.run`` retraces a fresh ``lax.scan`` per cell and
evaluates the full-batch global loss every iteration, so wall-clock is
dominated by tracing + monitoring.  ``run_sweep`` instead:

  * ``vmap``s one cell program over the flattened (variant, gamma, seed)
    grid, dispatching algorithm variants with ``lax.switch`` over a static
    per-config branch table — the grid compiles exactly ONCE;
  * thins monitoring to an ``eval_every`` stride: the scan is restructured
    as ``n_evals`` outer steps of ``eval_every`` fused micro-rounds, and the
    full-batch loss / distance-to-optimum are computed only at the outer
    step (``eval_every=1`` reproduces ``federated.run`` exactly);
  * donates the batched ``(w, ArtemisState)`` carry buffers to the compiled
    call so the grid state is updated in place;
  * optionally routes the Artemis uplink through the fused Pallas kernels
    (``backend='pallas'``: worker encode + memory update in one HBM pass,
    server dequant-accumulate via ``ring_sum``).

Bit metering follows the unified rule of DESIGN.md §4 (identical to
``federated.run``): per round, every active worker pays the uplink message
plus the downlink catch-up of all updates missed since its last
participation, capped at one full model (Remark 3).

Fault injection & self-healing (DESIGN.md §8): each cfg's
``ArtemisConfig.faults`` composes into its switch branch — Markov-correlated
participation, stragglers, gradient blowups (+ entry scrubbing), wire
corruption (handled inside ``artemis_round``) — and a per-cell divergence
sentinel at each eval point rolls the carry back to the last good snapshot
with geometric gamma backoff, all in-trace.  A zero-fault config emits the
byte-identical program (every fault path is statically gated).

Telemetry (DESIGN.md §11): ``run_sweep(telemetry=True)`` threads the
``repro.obs`` pure-pytree metrics carry through the scan — per-round
compression-error norms, participation/fault/rollback counters, the
Remark-3 bit ledger split, and the memory-drift ``mean_i ||h_i - grad
F_i(w*)||`` sampled at each eval point — and returns them as
``SweepResult.telemetry`` arrays on the eval grid.  The flag is STATIC:
``telemetry=False`` builds the byte-identical pre-telemetry program (same
trace, same compile count, bitwise-equal trajectories), and even when
enabled the PRNG streams and update path are untouched, so trajectories
match the untelemetered run bitwise.  No host callback ever runs inside
the scan; ``repro.obs.events.record_sweep`` writes the JSONL event log
from the returned arrays afterwards.

Resumable sweeps: ``run_sweep(checkpoint_dir=...)`` splits the outer scan
into ``checkpoint_every``-round segments through one compiled segment
program, snapshotting the batched carry + eval series after each segment
via ``checkpoint/checkpointer.py``; ``resume=True`` restarts mid-grid
bitwise (the carry round-trips exactly through npz).

Compiled executables are cached per (problem, grid statics), so repeated
calls with new gammas/seeds re-trace zero times.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import artemis as art
from repro.core import compression as comp
from repro.core import faults
from repro.core.federated import Problem
from repro.checkpoint import checkpointer
from repro.obs import spans as obs_spans
from repro.obs import telemetry as obs_tel

# incremented inside the traced sweep body: visible side effect only while
# tracing, so it counts XLA compilations of the grid program
_TRACE_COUNT = 0

# compiled-cell-program cache: (id(problem), static key) -> jitted fn.
# Each cached fn closes over its problem's arrays, keeping the id alive (so
# id-keying cannot alias a new object); bounded LRU so long-lived processes
# constructing many problems don't pin arrays/executables without limit.
_COMPILED: "dict" = {}
_COMPILED_MAX = 32


def trace_count() -> int:
    """Total sweep-program traces so far in this process."""
    return _TRACE_COUNT


@dataclasses.dataclass
class SweepResult:
    """Grid results, all leading axes [V(ariants), G(ammas), S(eeds)]."""
    losses: np.ndarray          # [V, G, S, E]  F(w) at each eval point
    bits: np.ndarray            # [V, G, S, E]  cumulative communicated bits
    dists: np.ndarray           # [V, G, S, E]  ||w - w*||; ||w|| if no w_star
    w_final: np.ndarray         # [V, G, S, d]
    w_avg: np.ndarray           # [V, G, S, d]  Polyak-Ruppert average
    w_tail_avg: np.ndarray      # [V, G, S, d]  average over the last half
    rollbacks: np.ndarray       # [V, G, S]  divergence-sentinel rollback count
    gamma_scale: np.ndarray     # [V, G, S]  final backoff multiplier on gamma
    eval_iters: np.ndarray      # [E] iteration index k of each eval point
    traces: int                 # compiles triggered by THIS call (0 if cached)
    # telemetry=True only: {metric: [V, G, S, E]} ([V, G, S, E, B] for
    # histograms), metric names from the repro.obs.telemetry catalogue
    telemetry: Optional[dict] = None

    def cell(self, v: int, g: int, s: int):
        """(losses, bits, dists) series of one grid cell."""
        return self.losses[v, g, s], self.bits[v, g, s], self.dists[v, g, s]


def _round_branch(cfg: art.ArtemisConfig, backend: Optional[str],
                  telemetry: bool = False):
    """One lax.switch branch: full round + unified bit metering for ``cfg``.

    All per-variant constants (compressor table entry, participation p,
    catch-up window, fault rates) are baked in statically, so the branch
    table is the "static compressor table" the grid switches over.
    """
    c_up, c_dwn = cfg.compressors()
    fc = faults.of(cfg.faults)
    if fc.markov:
        faults.markov_rates(fc, cfg.p)   # raise on infeasible chains at build
    d, n = cfg.dim, cfg.n_workers
    m1 = float(comp.FP_BITS * d)                 # full-model message
    m2 = max(c_dwn.bits(d), 1.0)                 # compressed-update message
    window = max(int(m1 // m2), 1)

    def branch(state, grads, u_act, k_art, last_part, k, prev_act, k_flt):
        # availability: i.i.d. Bernoulli(p), or the stationary-p Markov chain
        # (both consume the SAME uniform draw, so p_stay=p is bitwise i.i.d.)
        part = faults.participation(fc, cfg.p, u_act, prev_act, k)
        part = part.astype(grads.dtype)
        active = part
        strag_drops = blowup_hits = scrub_drops = 0.0
        if fc.straggler_rate > 0.0:
            # available but missed the round deadline: drops out of the round
            u_s = jax.random.uniform(jax.random.fold_in(k_flt, 1), (n,))
            avail = active
            active = active * (u_s >= fc.straggler_rate).astype(active.dtype)
            if telemetry:
                strag_drops = jnp.sum(avail) - jnp.sum(active)
        if fc.blowup_rate > 0.0:
            # the mask/apply split lets telemetry count hits off the SAME
            # Bernoulli draw — the fault stream is untouched either way
            hit = faults.blowup_mask(fc, jax.random.fold_in(k_flt, 2),
                                     grads.shape[0])
            grads = faults.apply_blowup(fc, hit, grads)
            if telemetry:
                blowup_hits = jnp.sum(hit.astype(jnp.float32))
        if fc.scrub:
            # non-finite gradient => worker masked inactive BEFORE any
            # arithmetic (0 * NaN is NaN, so zero the rows too)
            finite = jnp.all(jnp.isfinite(grads), axis=-1).astype(active.dtype)
            pre_scrub = active
            active = active * finite
            grads = faults.nan_to_zero(grads)
            if telemetry:
                scrub_drops = jnp.sum(pre_scrub) - jnp.sum(active)
        omega, state, stats = art.artemis_round(cfg, state, grads, k_art,
                                                active, backend=backend)
        missed = k - last_part                   # rounds since last download
        catch = jnp.where(missed > window, m1, missed.astype(jnp.float32) * m2)
        catch = jnp.sum(active * catch)
        last_part = jnp.where(active > 0, k, last_part).astype(jnp.int32)
        bits = stats["uplink_bits"] + catch
        if not telemetry:
            return omega, state, last_part, bits, part
        tel = obs_tel.sweep_round(
            avail=jnp.sum(part), active=jnp.sum(active),
            straggler_drops=strag_drops, blowup_hits=blowup_hits,
            entry_scrub_drops=scrub_drops,
            wire_scrubbed=stats["wire_scrubbed"],
            uplink_bits=stats["uplink_bits"],
            dwnlink_bits=stats["dwnlink_bits"], catchup_bits=catch,
            err_up=stats["compress_err_up"],
            err_dwn=stats["compress_err_dwn"],
            ghat_norm=stats["ghat_norm"])
        return omega, state, last_part, bits, part, tel

    return branch


def _static_key(problem: Problem, cfgs, iters, eval_every, batch, full_batch,
                gamma_decay, backend, seg_evals, telemetry) -> Tuple:
    return (id(problem), tuple(repr(c) for c in cfgs), iters, eval_every,
            batch, full_batch, gamma_decay, backend, seg_evals, telemetry)


def _sweep_fingerprint(problem: Problem, cfgs, iters, eval_every, batch,
                       full_batch, gamma_decay, backend, gms, keys, w0,
                       w_star) -> str:
    """Stable identity of a sweep for checkpoint resume (id() is not)."""
    h = hashlib.sha256()
    h.update(repr((tuple(repr(c) for c in cfgs), iters, eval_every, batch,
                   full_batch, gamma_decay, backend, problem.kind,
                   float(problem.reg), tuple(problem.X.shape))).encode())
    for arr in (problem.X, problem.Y, gms, keys, w0, w_star):
        h.update(np.asarray(jax.device_get(arr)).tobytes())
    return h.hexdigest()


def _build_sweep_fn(problem: Problem, cfgs: Sequence[art.ArtemisConfig],
                    iters: int, eval_every: int, batch: int, full_batch: bool,
                    gamma_decay: bool, backend: Optional[str],
                    seg_evals: Optional[int] = None,
                    telemetry: bool = False):
    """seg_evals=None: one donated whole-run program (the default).
    seg_evals=k: a resumable segment program over k eval strides; returns
    (seg_fn, init_fn, extract_fn).
    telemetry=True appends the repro.obs metrics accumulator as the LAST
    carry element and emits its per-eval reading as a 4th scan output —
    False builds the byte-identical legacy program (static gate)."""
    n, d = problem.n_workers, problem.dim
    n_per = problem.X.shape[1]
    n_evals = iters // eval_every
    branches = tuple(_round_branch(cfg, backend, telemetry) for cfg in cfgs)
    # any cell with a sentinel grows the carry by (gamma scale, good
    # snapshot, rollback count); cells without one keep thresh=0 => never bad
    any_rollback = any(faults.of(c.faults).rollback for c in cfgs)
    sent_by_v = np.array([faults.of(c.faults).sentinel for c in cfgs],
                         np.float32)
    back_by_v = np.array([faults.of(c.faults).backoff for c in cfgs],
                         np.float32)

    def init_carry(w0, st0):
        base = (w0, st0, jnp.zeros_like(w0), jnp.zeros_like(w0),
                -jnp.ones((n,), jnp.int32), jnp.zeros((), jnp.float32),
                jnp.zeros((n,), jnp.float32))
        if any_rollback:
            good0 = (w0, st0, jnp.zeros_like(w0), jnp.zeros_like(w0),
                     jnp.zeros((n,), jnp.float32), problem.global_loss(w0))
            base = base + (jnp.ones(()), good0, jnp.zeros((), jnp.int32))
        if telemetry:
            base = base + (obs_tel.sweep_zeros(),)
        return base

    def make_outer(vi, gamma, key, w_star):
        """The eval-stride scan body of one grid cell."""
        # memory-drift reference grad F_i(w*): hoisted out of the scan —
        # computed once per cell, only when telemetry asks for it
        g_star = problem.full_grad(w_star) if telemetry else None

        def micro(carry, k):
            if telemetry:
                carry, tel_acc = carry[:-1], carry[-1]
            if any_rollback:
                (w, st, wsum, wtail, last_part, bits, prev_act,
                 gscale, good, rb) = carry
            else:
                w, st, wsum, wtail, last_part, bits, prev_act = carry
            kk = jax.random.fold_in(key, k)
            k_idx, k_act, k_art = jax.random.split(kk, 3)
            # fault stream: salted off kk so the base draws are untouched
            k_flt = jax.random.fold_in(kk, faults.FAULT_SALT)
            if full_batch:
                grads = problem.full_grad(w)
            else:
                idx = jax.random.randint(k_idx, (n, batch), 0, n_per)
                grads = problem.worker_grad(w, idx)
            u_act = jax.random.uniform(k_act, (n,))
            sw = jax.lax.switch(
                vi, branches, st, grads, u_act, k_art, last_part, k,
                prev_act, k_flt)
            if telemetry:
                omega, st, last_part, round_bits, prev_act, tel = sw
                tel_acc = obs_tel.sweep_accumulate(tel_acc, tel)
            else:
                omega, st, last_part, round_bits, prev_act = sw
            g = gamma / jnp.sqrt(k + 1.0) if gamma_decay else gamma
            if any_rollback:
                g = g * gscale               # exact no-op while gscale == 1
            w = w - g * omega
            wtail = wtail + jnp.where(k >= iters // 2, 1.0, 0.0) * w
            base = (w, st, wsum + w, wtail, last_part, bits + round_bits,
                    prev_act)
            if any_rollback:
                base = base + (gscale, good, rb)
            if telemetry:
                base = base + (tel_acc,)
            return base, None

        if any_rollback:
            thr = jnp.asarray(sent_by_v)[vi]
            bo = jnp.asarray(back_by_v)[vi]

        def emit_and_pack(tel_acc, st, rb, loss, bits, dist):
            """Eval-point telemetry reading (post rollback selection)."""
            emit = obs_tel.sweep_emit(
                tel_acc, eval_every,
                mem_drift=jnp.mean(jnp.linalg.norm(st.h - g_star, axis=-1)),
                e_norm=jnp.mean(jnp.linalg.norm(st.e, axis=-1)),
                rollbacks=rb)
            return obs_tel.sweep_reset_stride(tel_acc), (loss, bits, dist,
                                                         emit)

        def outer(carry, e):
            ks = e * eval_every + jnp.arange(eval_every)
            carry, _ = jax.lax.scan(micro, carry, ks)
            if telemetry:
                carry, tel_acc = carry[:-1], carry[-1]
            if not any_rollback:
                w, st, _, _, _, bits, _ = carry
                loss = problem.global_loss(w)
                dist = jnp.linalg.norm(w - w_star)
                if not telemetry:
                    return carry, (loss, bits, dist)
                tel_acc, out = emit_and_pack(tel_acc, st,
                                             jnp.zeros((), jnp.int32),
                                             loss, bits, dist)
                return carry + (tel_acc,), out
            (w, st, wsum, wtail, last_part, bits, prev_act,
             gscale, good, rb) = carry
            loss = problem.global_loss(w)
            # NaN/Inf compare False => bad; thr == 0 disables the sentinel
            ok = (loss <= thr) & (jnp.linalg.norm(w) <= thr)
            bad = (thr > 0) & ~ok
            cur = (w, st, wsum, wtail, prev_act, loss)
            w, st, wsum, wtail, prev_act, loss = jax.tree.map(
                lambda gl, cl: jnp.where(bad, gl, cl), good, cur)
            gscale = jnp.where(bad, gscale * bo, gscale)
            rb = rb + bad.astype(jnp.int32)
            # post-select, (w, ...) IS the last good state either way
            good = (w, st, wsum, wtail, prev_act, loss)
            dist = jnp.linalg.norm(w - w_star)
            carry = (w, st, wsum, wtail, last_part, bits, prev_act,
                     gscale, good, rb)
            if not telemetry:
                return carry, (loss, bits, dist)
            tel_acc, out = emit_and_pack(tel_acc, st, rb, loss, bits, dist)
            return carry + (tel_acc,), out

        return outer

    def extract(carry):
        """Final per-cell results from a (possibly batched) carry."""
        if telemetry:
            carry = carry[:-1]
        if any_rollback:
            w, _, wsum, wtail, _, _, _, gscale, _, rb = carry
        else:
            w, _, wsum, wtail, _, _, _ = carry
            rb = jnp.zeros(w.shape[:-1], jnp.int32)
            gscale = jnp.ones(w.shape[:-1], jnp.float32)
        return (w, wsum / iters, wtail / max(iters - iters // 2, 1),
                rb, gscale)

    if seg_evals is not None:
        def cell_seg(carry, vi, gamma, key, w_star, e0):
            outer = make_outer(vi, gamma, key, w_star)
            es = e0 + jnp.arange(seg_evals)
            return jax.lax.scan(outer, carry, es)

        def sweep_seg(carry, vis, gammas, keys, w_star, e0):
            global _TRACE_COUNT                # repro-lint: allow=jit-mutable-global
            _TRACE_COUNT += 1                  # trace counter, trace-time only
            return jax.vmap(cell_seg, in_axes=(0, 0, 0, 0, None, None))(
                carry, vis, gammas, keys, w_star, e0)

        # no donation: the carry must stay alive to be checkpointed after
        # every segment call
        return jax.jit(sweep_seg), init_carry, extract

    def cell(w0, st0, vi, gamma, key, w_star):
        """One grid cell: variant ``vi`` at step size ``gamma`` under ``key``."""
        outer = make_outer(vi, gamma, key, w_star)
        return jax.lax.scan(outer, init_carry(w0, st0), jnp.arange(n_evals))

    def sweep(w0b, st0b, vis, gammas, keys, w_star):
        global _TRACE_COUNT                    # repro-lint: allow=jit-mutable-global
        _TRACE_COUNT += 1                      # trace counter, trace-time only
        # NOTE: vmap of lax.switch over a batched index evaluates every
        # branch and selects, so each cell pays V x the round arithmetic.
        # That is the deliberate trade for compiling the whole grid ONCE:
        # cells are tiny and retracing dominates (19x measured win on the
        # paper grid).  run_sweep(group_by_variant=True) flips the trade —
        # V single-variant traces, 1x arithmetic — which wins once per-round
        # work dwarfs trace cost (big d/iters; crossover in DESIGN.md §5).
        return jax.vmap(cell, in_axes=(0, 0, 0, 0, 0, None))(
            w0b, st0b, vis, gammas, keys, w_star)

    # donate the batched (w, ArtemisState) carries: the grid state buffers
    # are consumed by the compiled call instead of being copied.  extract
    # runs OUTSIDE the jit (like the segmented path) so w_avg/w_tail_avg
    # come off the exact same division in both modes — fusing the divide
    # into the cell program moves them by an ulp vs the segmented run.
    return jax.jit(sweep, donate_argnums=(0, 1)), extract


def _prepare_grid(problem: Problem, cfgs, gammas, seeds, w0, w_star):
    """Flatten the {V}x{G}x{S} grid into the batched arguments the compiled
    sweep consumes (variant-major, then gamma, then seed — C-order).

    Shared by ``run_sweep`` (execution) and ``lower_sweep`` (AOT analysis):
    both must see byte-identical argument shapes or the executable cache
    splits."""
    d = problem.dim
    gammas = jnp.asarray(gammas, jnp.float32).reshape(-1)
    seeds = np.asarray(seeds)
    if seeds.ndim == 2 and seeds.shape[-1] == 2:     # explicit PRNG keys
        cell_keys = jnp.asarray(seeds, jnp.uint32)
    else:
        cell_keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(seeds.reshape(-1)))
    V, G, S = len(cfgs), gammas.shape[0], cell_keys.shape[0]
    C = V * G * S
    vis = jnp.repeat(jnp.arange(V, dtype=jnp.int32), G * S)
    gms = jnp.tile(jnp.repeat(gammas, S), V)
    keys = jnp.tile(cell_keys, (V * G, 1))
    w0 = jnp.zeros((d,)) if w0 is None else jnp.asarray(w0)
    w0b = jnp.broadcast_to(w0, (C, d)).copy()            # donated below
    st0 = art.init_state(cfgs[0])
    st0b = jax.tree.map(lambda x: jnp.broadcast_to(x, (C,) + x.shape).copy(),
                        st0)
    ws = jnp.zeros((d,)) if w_star is None else jnp.asarray(w_star)
    return (V, G, S, C), (w0b, st0b, vis, gms, keys, ws), w0


def lower_sweep(problem: Problem, cfgs: Sequence[art.ArtemisConfig],
                gammas, seeds, iters: int, *, batch: int = 1,
                eval_every: int = 1, full_batch: bool = False,
                w0: Optional[jax.Array] = None,
                w_star: Optional[jax.Array] = None,
                gamma_decay: bool = False,
                backend: Optional[str] = None,
                telemetry: bool = False):
    """AOT-lower the grid program without executing it.

    Returns ``jax.stages.Lowered`` for exactly the program ``run_sweep``
    would run (same builder, same argument shapes).  ``repro.analysis``'s
    HLO layer inspects its StableHLO for the donated-carry
    ``tf.aliasing_output`` attributes and for host transfers; callers can
    also ``.compile()`` it to warm the cache or read the optimized HLO."""
    if iters % eval_every != 0:
        raise ValueError(f"iters={iters} not divisible by "
                         f"eval_every={eval_every}")
    sweep_fn, _ = _build_sweep_fn(problem, cfgs, iters, eval_every, batch,
                                  full_batch, gamma_decay, backend, None,
                                  telemetry)
    _, args, _ = _prepare_grid(problem, cfgs, gammas, seeds, w0, w_star)
    return sweep_fn.lower(*args)


@contextlib.contextmanager
def _donation_guard():
    """Surface real donation failures instead of blanket-suppressing them.

    jax warns ``Some donated buffers were not usable`` when a donation
    request is dropped.  On CPU backends without donation support that is
    expected noise — but on TPU/GPU it means the in-place grid-carry update
    (and its memory headroom) silently regressed, so it is promoted to an
    error pointing at the static aliasing audit.  Unrelated warnings are
    re-emitted untouched.  The positive guarantee (donated carries DO appear
    in ``input_output_alias``) is checked statically by
    ``repro.analysis.hlo_checks`` on ``lower_sweep``'s StableHLO."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        yield
    for w in rec:
        if "donated buffers" in str(w.message):
            if jax.default_backend() in ("tpu", "gpu", "cuda", "rocm"):
                raise RuntimeError(
                    f"sweep carry donation was dropped on "
                    f"{jax.default_backend()!r}: {w.message} — the grid no "
                    f"longer updates in place; run `python -m repro.analysis`"
                    f" (hlo-missing-donation) to locate the unaliased carry")
            continue        # CPU: donation unsupported there, nothing lost
        warnings.warn_explicit(w.message, w.category, w.filename, w.lineno)


def run_sweep(problem: Problem, cfgs: Sequence[art.ArtemisConfig],
              gammas, seeds, iters: int, *, batch: int = 1,
              eval_every: int = 1, full_batch: bool = False,
              w0: Optional[jax.Array] = None,
              w_star: Optional[jax.Array] = None,
              gamma_decay: bool = False,
              backend: Optional[str] = None,
              group_by_variant: bool = False,
              checkpoint_dir: Optional[str] = None,
              checkpoint_every: Optional[int] = None,
              resume: bool = False,
              telemetry: bool = False) -> SweepResult:
    """Run the full {cfgs} x {gammas} x {seeds} grid in one compiled call.

    Args:
      problem: the federated Problem (shared by every cell).
      cfgs: V ArtemisConfigs (one per algorithm variant); all must share
        ``dim``/``n_workers`` with ``problem``.
      gammas: G step sizes.
      seeds: S integer seeds (each becomes an independent PRNG stream), or
        an [S, 2] stack of explicit uint32 PRNG keys.
      iters: rounds per cell; must be divisible by ``eval_every``.
      eval_every: monitoring stride — loss/distance are computed once per
        ``eval_every`` rounds (1 == per-round, matching ``federated.run``).
      backend: None -> each cfg's own backend; 'dense'/'pallas' to override.
      group_by_variant: partition the grid into V single-variant sub-sweeps
        sharing the executable cache, instead of one vmap-of-lax.switch
        program.  Each sub-sweep's switch has ONE branch, so cells pay 1x
        (not V x) the round arithmetic at the price of V traces on the first
        call — the win for large problems / long runs (DESIGN.md §5).
        Results are identical up to f32 batched-reduction reassociation.
      checkpoint_dir: enable resumable mode — run the sweep in segments and
        snapshot the batched carry + eval series after each one.  The
        trajectory is bitwise identical to the plain run (same scan body;
        f32/int32 round-trip exactly through npz).
      checkpoint_every: rounds between snapshots (default ``eval_every``);
        must divide ``iters`` and be a multiple of ``eval_every``.
      resume: restart from the latest snapshot in ``checkpoint_dir`` if one
        exists (validated against a sweep fingerprint; a foreign checkpoint
        raises ValueError).  No snapshot -> fresh start.
      telemetry: thread the repro.obs in-trace metrics carry through the
        scan and return per-eval-point readings as ``SweepResult.telemetry``
        (DESIGN.md §11).  Static gate: False is the byte-identical legacy
        program; True leaves trajectories bitwise unchanged (the PRNG
        streams and update path are untouched).  Not supported together
        with ``checkpoint_dir`` (the snapshot format pins the carry).

    Returns a SweepResult with [V, G, S, ...] arrays.
    """
    if telemetry and checkpoint_dir is not None:
        raise ValueError("telemetry=True is not supported with "
                         "checkpoint_dir (the checkpoint carry format does "
                         "not include the metrics accumulator); run the "
                         "instrumented sweep unsegmented")
    if checkpoint_dir is not None and group_by_variant:
        raise ValueError("checkpointing is not supported with "
                         "group_by_variant=True (V independent sub-sweeps "
                         "would race on one checkpoint directory)")
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir")
    if checkpoint_every is not None and checkpoint_dir is None:
        raise ValueError("checkpoint_every requires checkpoint_dir")
    if group_by_variant and len(cfgs) > 1:
        parts = [run_sweep(problem, [cfg], gammas, seeds, iters, batch=batch,
                           eval_every=eval_every, full_batch=full_batch,
                           w0=w0, w_star=w_star, gamma_decay=gamma_decay,
                           backend=backend, telemetry=telemetry)
                 for cfg in cfgs]
        arr = {f.name: np.concatenate([getattr(p, f.name) for p in parts],
                                      axis=0)
               for f in dataclasses.fields(SweepResult)
               if f.name not in ("eval_iters", "traces", "telemetry")}
        tel = None
        if telemetry:
            tel = {k: np.concatenate([p.telemetry[k] for p in parts], axis=0)
                   for k in parts[0].telemetry}
        return SweepResult(eval_iters=parts[0].eval_iters,
                           traces=sum(p.traces for p in parts),
                           telemetry=tel, **arr)
    if iters % eval_every != 0:
        raise ValueError(f"iters={iters} not divisible by eval_every={eval_every}")
    for cfg in cfgs:
        if (cfg.dim, cfg.n_workers) != (problem.dim, problem.n_workers):
            raise ValueError(f"cfg {cfg} does not match problem "
                             f"(d={problem.dim}, N={problem.n_workers})")
    seg_evals = None
    if checkpoint_dir is not None:
        checkpoint_every = eval_every if checkpoint_every is None \
            else checkpoint_every
        if checkpoint_every % eval_every != 0 or iters % checkpoint_every != 0:
            raise ValueError(
                f"checkpoint_every={checkpoint_every} must be a multiple of "
                f"eval_every={eval_every} and divide iters={iters}")
        seg_evals = checkpoint_every // eval_every
    (V, G, S, C), (w0b, st0b, vis, gms, keys, ws), w0 = _prepare_grid(
        problem, cfgs, gammas, seeds, w0, w_star)

    key = _static_key(problem, cfgs, iters, eval_every, batch, full_batch,
                      gamma_decay, backend, seg_evals, telemetry)
    if key not in _COMPILED:
        while len(_COMPILED) >= _COMPILED_MAX:          # bounded LRU
            _COMPILED.pop(next(iter(_COMPILED)))
        _COMPILED[key] = _build_sweep_fn(
            problem, cfgs, iters, eval_every, batch, full_batch, gamma_decay,
            backend, seg_evals, telemetry)
    else:
        _COMPILED[key] = _COMPILED.pop(key)             # mark recently used
    fn = _COMPILED[key]

    before = _TRACE_COUNT
    if seg_evals is not None:
        losses, bits, dists, w_fin, w_avg, w_tail, rb, gscale = \
            _run_segmented(fn, problem, cfgs, iters, eval_every, batch,
                           full_batch, gamma_decay, backend, seg_evals,
                           checkpoint_dir, resume, w0b, st0b, vis, gms, keys,
                           w0, ws, C)
    else:
        sweep_fn, extract = fn
        # a cold call traces+compiles inside this span, a warm one times
        # pure execution; res.traces says which it was, so the span ledger
        # (or any installed event sink) yields the compile/execute split
        with _donation_guard(), obs_spans.span("sweep/execute",
                                               cells=int(C)):
            carry, ys = jax.block_until_ready(
                sweep_fn(w0b, st0b, vis, gms, keys, ws))
        if telemetry:
            losses, bits, dists, tel_out = ys
        else:
            losses, bits, dists = ys
        w_fin, w_avg, w_tail, rb, gscale = extract(carry)

    def _grid(x):
        x = np.asarray(x)
        return x.reshape((V, G, S) + x.shape[1:])

    tel = None
    if telemetry:
        # [C, E(, B)] per metric -> [V, G, S, E(, B)] host arrays
        tel = {k: _grid(v) for k, v in tel_out.items()}

    return SweepResult(
        telemetry=tel,
        losses=_grid(losses),
        bits=_grid(bits),
        dists=_grid(dists),
        w_final=_grid(w_fin),
        w_avg=_grid(w_avg),
        w_tail_avg=_grid(w_tail),
        rollbacks=_grid(rb),
        gamma_scale=_grid(gscale),
        eval_iters=np.arange(1, iters // eval_every + 1) * eval_every - 1,
        traces=_TRACE_COUNT - before,
    )


def _run_segmented(fn, problem, cfgs, iters, eval_every, batch, full_batch,
                   gamma_decay, backend, seg_evals, checkpoint_dir, resume,
                   w0b, st0b, vis, gms, keys, w0, ws, C):
    """Drive the segment program checkpoint-to-checkpoint (see run_sweep)."""
    seg_fn, init_carry, extract = fn
    n_evals = iters // eval_every
    n_segs = n_evals // seg_evals
    fp = _sweep_fingerprint(problem, cfgs, iters, eval_every, batch,
                            full_batch, gamma_decay, backend, gms, keys,
                            w0, ws)
    carry = jax.vmap(init_carry)(w0b, st0b)
    series = {k: np.zeros((C, n_evals), np.float32)
              for k in ("losses", "bits", "dists")}
    e_done = 0
    if resume and checkpointer.latest_step(checkpoint_dir) is not None:
        man = checkpointer.read_manifest(checkpoint_dir)
        extra = man.get("extra", {})
        if extra.get("fingerprint") != fp:
            raise ValueError(
                f"checkpoint in {checkpoint_dir} belongs to a different "
                f"sweep (fingerprint mismatch); refusing to resume")
        like = {"carry": carry,
                "series": {k: jnp.zeros((C, n_evals), jnp.float32)
                           for k in series}}
        tree = checkpointer.restore(checkpoint_dir, like)
        carry = tree["carry"]
        for k in series:
            series[k][:] = np.asarray(tree["series"][k])
        e_done = int(extra["e_done"])
    for si in range(e_done // seg_evals, n_segs):
        e0 = si * seg_evals
        with obs_spans.span("sweep/segment", e0=int(e0)):
            carry, (l, b, dd) = seg_fn(carry, vis, gms, keys, ws,
                                       jnp.asarray(e0, jnp.int32))
            jax.block_until_ready(carry)
        sl = slice(e0, e0 + seg_evals)
        series["losses"][:, sl] = np.asarray(l)
        series["bits"][:, sl] = np.asarray(b)
        series["dists"][:, sl] = np.asarray(dd)
        e_done = e0 + seg_evals
        checkpointer.save(
            checkpoint_dir, e_done, {"carry": carry, "series": series},
            extra={"fingerprint": fp, "e_done": e_done, "n_evals": n_evals})
    w_fin, w_avg, w_tail, rb, gscale = extract(carry)
    return (series["losses"], series["bits"], series["dists"],
            w_fin, w_avg, w_tail, rb, gscale)
