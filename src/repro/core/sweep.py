"""Batched sweep engine: the whole {variant} x {gamma} x {seed} grid in ONE
compiled program.

The paper's experiment grids (§5, Figs. 2-6) are dozens of cells; running
them through ``federated.run`` retraces a fresh ``lax.scan`` per cell and
evaluates the full-batch global loss every iteration, so wall-clock is
dominated by tracing + monitoring.  ``run_sweep`` instead:

  * ``vmap``s one cell program over the flattened (variant, gamma, seed)
    grid, dispatching algorithm variants with ``lax.switch`` over a static
    per-config branch table — the grid compiles exactly ONCE;
  * thins monitoring to an ``eval_every`` stride: the scan is restructured
    as ``n_evals`` outer steps of ``eval_every`` fused micro-rounds, and the
    full-batch loss / distance-to-optimum are computed only at the outer
    step (``eval_every=1`` reproduces ``federated.run`` exactly);
  * donates the batched ``(w, ArtemisState)`` carry buffers to the compiled
    call so the grid state is updated in place;
  * optionally routes the Artemis uplink through the fused Pallas kernels
    (``backend='pallas'``: worker encode + memory update in one HBM pass,
    server dequant-accumulate via ``ring_sum``).

Bit metering follows the unified rule of DESIGN.md §4 (identical to
``federated.run``): per round, every active worker pays the uplink message
plus the downlink catch-up of all updates missed since its last
participation, capped at one full model (Remark 3).

Compiled executables are cached per (problem, grid statics), so repeated
calls with new gammas/seeds re-trace zero times.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import artemis as art
from repro.core import compression as comp
from repro.core.federated import Problem

# incremented inside the traced sweep body: visible side effect only while
# tracing, so it counts XLA compilations of the grid program
_TRACE_COUNT = 0

# compiled-cell-program cache: (id(problem), static key) -> jitted fn.
# Each cached fn closes over its problem's arrays, keeping the id alive (so
# id-keying cannot alias a new object); bounded LRU so long-lived processes
# constructing many problems don't pin arrays/executables without limit.
_COMPILED: "dict" = {}
_COMPILED_MAX = 32


def trace_count() -> int:
    """Total sweep-program traces so far in this process."""
    return _TRACE_COUNT


@dataclasses.dataclass
class SweepResult:
    """Grid results, all leading axes [V(ariants), G(ammas), S(eeds)]."""
    losses: np.ndarray          # [V, G, S, E]  F(w) at each eval point
    bits: np.ndarray            # [V, G, S, E]  cumulative communicated bits
    dists: np.ndarray           # [V, G, S, E]  ||w - w*||; ||w|| if no w_star
    w_final: np.ndarray         # [V, G, S, d]
    w_avg: np.ndarray           # [V, G, S, d]  Polyak-Ruppert average
    w_tail_avg: np.ndarray      # [V, G, S, d]  average over the last half
    eval_iters: np.ndarray      # [E] iteration index k of each eval point
    traces: int                 # compiles triggered by THIS call (0 if cached)

    def cell(self, v: int, g: int, s: int):
        """(losses, bits, dists) series of one grid cell."""
        return self.losses[v, g, s], self.bits[v, g, s], self.dists[v, g, s]


def _round_branch(cfg: art.ArtemisConfig, backend: Optional[str]):
    """One lax.switch branch: full round + unified bit metering for ``cfg``.

    All per-variant constants (compressor table entry, participation p,
    catch-up window) are baked in statically, so the branch table is the
    "static compressor table" the grid switches over.
    """
    c_up, c_dwn = cfg.compressors()
    d, n = cfg.dim, cfg.n_workers
    m1 = float(comp.FP_BITS * d)                 # full-model message
    m2 = max(c_dwn.bits(d), 1.0)                 # compressed-update message
    window = max(int(m1 // m2), 1)

    def branch(state, grads, u_act, k_art, last_part, k):
        active = (u_act < cfg.p).astype(grads.dtype)
        omega, state, stats = art.artemis_round(cfg, state, grads, k_art,
                                                active, backend=backend)
        missed = k - last_part                   # rounds since last download
        catch = jnp.where(missed > window, m1, missed.astype(jnp.float32) * m2)
        catch = jnp.sum(active * catch)
        last_part = jnp.where(active > 0, k, last_part).astype(jnp.int32)
        bits = stats["uplink_bits"] + catch
        return omega, state, last_part, bits

    return branch


def _static_key(problem: Problem, cfgs, iters, eval_every, batch, full_batch,
                gamma_decay, backend) -> Tuple:
    return (id(problem), tuple(repr(c) for c in cfgs), iters, eval_every,
            batch, full_batch, gamma_decay, backend)


def _build_sweep_fn(problem: Problem, cfgs: Sequence[art.ArtemisConfig],
                    iters: int, eval_every: int, batch: int, full_batch: bool,
                    gamma_decay: bool, backend: Optional[str]):
    n, d = problem.n_workers, problem.dim
    n_per = problem.X.shape[1]
    n_evals = iters // eval_every
    branches = tuple(_round_branch(cfg, backend) for cfg in cfgs)

    def cell(w0, st0, vi, gamma, key, w_star):
        """One grid cell: variant ``vi`` at step size ``gamma`` under ``key``."""

        def micro(carry, k):
            w, st, wsum, wtail, last_part, bits = carry
            kk = jax.random.fold_in(key, k)
            k_idx, k_act, k_art = jax.random.split(kk, 3)
            if full_batch:
                grads = problem.full_grad(w)
            else:
                idx = jax.random.randint(k_idx, (n, batch), 0, n_per)
                grads = problem.worker_grad(w, idx)
            u_act = jax.random.uniform(k_act, (n,))
            omega, st, last_part, round_bits = jax.lax.switch(
                vi, branches, st, grads, u_act, k_art, last_part, k)
            g = gamma / jnp.sqrt(k + 1.0) if gamma_decay else gamma
            w = w - g * omega
            wtail = wtail + jnp.where(k >= iters // 2, 1.0, 0.0) * w
            return (w, st, wsum + w, wtail, last_part, bits + round_bits), None

        def outer(carry, e):
            ks = e * eval_every + jnp.arange(eval_every)
            carry, _ = jax.lax.scan(micro, carry, ks)
            w, _, _, _, _, bits = carry
            loss = problem.global_loss(w)
            dist = jnp.linalg.norm(w - w_star)
            return carry, (loss, bits, dist)

        carry0 = (w0, st0, jnp.zeros_like(w0), jnp.zeros_like(w0),
                  -jnp.ones((n,), jnp.int32), jnp.zeros((), jnp.float32))
        (w, _, wsum, wtail, _, _), (losses, bits, dists) = jax.lax.scan(
            outer, carry0, jnp.arange(n_evals))
        return (losses, bits, dists, w, wsum / iters,
                wtail / max(iters - iters // 2, 1))

    def sweep(w0b, st0b, vis, gammas, keys, w_star):
        global _TRACE_COUNT
        _TRACE_COUNT += 1                      # runs only while tracing
        # NOTE: vmap of lax.switch over a batched index evaluates every
        # branch and selects, so each cell pays V x the round arithmetic.
        # That is the deliberate trade for compiling the whole grid ONCE:
        # cells are tiny and retracing dominates (19x measured win on the
        # paper grid).  run_sweep(group_by_variant=True) flips the trade —
        # V single-variant traces, 1x arithmetic — which wins once per-round
        # work dwarfs trace cost (big d/iters; crossover in DESIGN.md §5).
        return jax.vmap(cell, in_axes=(0, 0, 0, 0, 0, None))(
            w0b, st0b, vis, gammas, keys, w_star)

    # donate the batched (w, ArtemisState) carries: the grid state buffers
    # are consumed by the compiled call instead of being copied
    return jax.jit(sweep, donate_argnums=(0, 1))


def run_sweep(problem: Problem, cfgs: Sequence[art.ArtemisConfig],
              gammas, seeds, iters: int, *, batch: int = 1,
              eval_every: int = 1, full_batch: bool = False,
              w0: Optional[jax.Array] = None,
              w_star: Optional[jax.Array] = None,
              gamma_decay: bool = False,
              backend: Optional[str] = None,
              group_by_variant: bool = False) -> SweepResult:
    """Run the full {cfgs} x {gammas} x {seeds} grid in one compiled call.

    Args:
      problem: the federated Problem (shared by every cell).
      cfgs: V ArtemisConfigs (one per algorithm variant); all must share
        ``dim``/``n_workers`` with ``problem``.
      gammas: G step sizes.
      seeds: S integer seeds (each becomes an independent PRNG stream), or
        an [S, 2] stack of explicit uint32 PRNG keys.
      iters: rounds per cell; must be divisible by ``eval_every``.
      eval_every: monitoring stride — loss/distance are computed once per
        ``eval_every`` rounds (1 == per-round, matching ``federated.run``).
      backend: None -> each cfg's own backend; 'dense'/'pallas' to override.
      group_by_variant: partition the grid into V single-variant sub-sweeps
        sharing the executable cache, instead of one vmap-of-lax.switch
        program.  Each sub-sweep's switch has ONE branch, so cells pay 1x
        (not V x) the round arithmetic at the price of V traces on the first
        call — the win for large problems / long runs (DESIGN.md §5).
        Results are identical up to f32 batched-reduction reassociation.

    Returns a SweepResult with [V, G, S, ...] arrays.
    """
    if group_by_variant and len(cfgs) > 1:
        parts = [run_sweep(problem, [cfg], gammas, seeds, iters, batch=batch,
                           eval_every=eval_every, full_batch=full_batch,
                           w0=w0, w_star=w_star, gamma_decay=gamma_decay,
                           backend=backend)
                 for cfg in cfgs]
        arr = {f.name: np.concatenate([getattr(p, f.name) for p in parts],
                                      axis=0)
               for f in dataclasses.fields(SweepResult)
               if f.name not in ("eval_iters", "traces")}
        return SweepResult(eval_iters=parts[0].eval_iters,
                           traces=sum(p.traces for p in parts), **arr)
    if iters % eval_every != 0:
        raise ValueError(f"iters={iters} not divisible by eval_every={eval_every}")
    for cfg in cfgs:
        if (cfg.dim, cfg.n_workers) != (problem.dim, problem.n_workers):
            raise ValueError(f"cfg {cfg} does not match problem "
                             f"(d={problem.dim}, N={problem.n_workers})")
    d = problem.dim
    gammas = jnp.asarray(gammas, jnp.float32).reshape(-1)
    seeds = np.asarray(seeds)
    if seeds.ndim == 2 and seeds.shape[-1] == 2:     # explicit PRNG keys
        cell_keys = jnp.asarray(seeds, jnp.uint32)
    else:
        cell_keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(seeds.reshape(-1)))
    V, G, S = len(cfgs), gammas.shape[0], cell_keys.shape[0]
    C = V * G * S

    key = _static_key(problem, cfgs, iters, eval_every, batch, full_batch,
                      gamma_decay, backend)
    if key not in _COMPILED:
        while len(_COMPILED) >= _COMPILED_MAX:          # bounded LRU
            _COMPILED.pop(next(iter(_COMPILED)))
        _COMPILED[key] = _build_sweep_fn(
            problem, cfgs, iters, eval_every, batch, full_batch, gamma_decay,
            backend)
    else:
        _COMPILED[key] = _COMPILED.pop(key)             # mark recently used
    fn = _COMPILED[key]

    # flattened grid: variant-major, then gamma, then seed (C-order)
    vis = jnp.repeat(jnp.arange(V, dtype=jnp.int32), G * S)
    gms = jnp.tile(jnp.repeat(gammas, S), V)
    keys = jnp.tile(cell_keys, (V * G, 1))

    w0 = jnp.zeros((d,)) if w0 is None else jnp.asarray(w0)
    w0b = jnp.broadcast_to(w0, (C, d)).copy()            # donated below
    st0 = art.init_state(cfgs[0])
    st0b = jax.tree.map(lambda x: jnp.broadcast_to(x, (C,) + x.shape).copy(), st0)
    ws = jnp.zeros((d,)) if w_star is None else jnp.asarray(w_star)

    before = _TRACE_COUNT
    with warnings.catch_warnings():
        # CPU has no donation support; the request still helps on TPU/GPU
        warnings.filterwarnings("ignore", message="Some donated buffers")
        losses, bits, dists, w_fin, w_avg, w_tail = jax.block_until_ready(
            fn(w0b, st0b, vis, gms, keys, ws))

    def _grid(x):
        return np.asarray(x).reshape((V, G, S) + x.shape[1:])

    return SweepResult(
        losses=_grid(losses),
        bits=_grid(bits),
        dists=_grid(dists),
        w_final=_grid(w_fin),
        w_avg=_grid(w_avg),
        w_tail_avg=_grid(w_tail),
        eval_iters=np.arange(1, iters // eval_every + 1) * eval_every - 1,
        traces=_TRACE_COUNT - before,
    )
