"""Mesh-distributed Artemis: compressed gradient aggregation over a worker axis.

Workers are slices of the device mesh along ``worker_axes`` (the 'pod' axis on
the production multi-pod mesh: the slow DCN inter-pod links play the paper's
bandwidth-constrained uplink/downlink).  The train step is wrapped in a
*partial-manual* ``jax.shard_map``: worker axes are manual — so ``jax.grad``
inside yields the per-worker gradient, un-psum'd — while the remaining
data/model axes stay auto, letting GSPMD shard the model inside each worker
exactly as in the uncompressed baseline.

Wire format is real: the uplink all-gathers **int8 levels + per-row f32
scales** across workers (visible in compiled HLO as int8 collectives — the
roofline's collective term measures the true byte reduction), then each
worker dequantizes and reduces locally.  The downlink broadcast costs ZERO
bytes: every worker compresses the identical aggregate with an identical
PRNG key (the TPU-native replacement for the server->worker broadcast).

State per paper Algorithm 1 (PP2):
  h    — per-worker memory h_i; global layout [W, ...] sharded over the
         worker axes (each worker owns its slice).
  hbar — server memory \bar h; replicated (every worker updates it with the
         same psum'd quantity, so it stays bitwise identical).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

VARIANTS = ("sgd", "qsgd", "diana", "biqsgd", "artemis", "dore")


@dataclasses.dataclass(frozen=True)
class DistConfig:
    worker_axes: Tuple[str, ...] = ("pod",)
    variant: str = "artemis"
    s: int = 1                      # quantization levels
    alpha: Optional[float] = None   # None -> 1/(2(omega+1)), omega = sqrt(row)/s
    p_participation: float = 1.0    # PP2 over workers when < 1
    memory_dtype: str = "float32"   # h storage dtype (bf16 = beyond-paper)
    error_feedback: bool = False    # Dore-style EF on the uplink (beyond paper)
    local_steps: int = 1            # communicate every k steps (Remark 2 /
                                    # Local-SGD direction; 1 = every step)
    seed: int = 17

    @property
    def up_compress(self) -> bool:
        return self.variant in ("qsgd", "diana", "biqsgd", "artemis", "dore")

    @property
    def dwn_compress(self) -> bool:
        return self.variant in ("biqsgd", "artemis", "dore")

    @property
    def memory(self) -> bool:
        return self.variant in ("diana", "artemis", "dore")

    @property
    def use_ef(self) -> bool:
        return self.error_feedback or self.variant == "dore"


# ---------------------------------------------------------------------------
# distributed-friendly per-row s-quantization (sharding-transparent)
# ---------------------------------------------------------------------------

def _row_norms(x: jax.Array) -> jax.Array:
    if x.ndim == 0:
        return jnp.abs(x)
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1,
                            keepdims=True))


def squant_encode(key: jax.Array, x: jax.Array, s: int):
    """Per-row stochastic s-quantization -> (levels int8, scales f32).

    Row-wise scales keep every op elementwise or a last-axis reduction, so
    GSPMD shards it without data movement beyond a tiny partial-norm reduce.
    """
    xf = x.astype(jnp.float32)
    norm = _row_norms(xf)
    scale = norm / s
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(xf) / safe * s
    low = jnp.floor(r)
    u = jax.random.uniform(key, x.shape, jnp.float32)
    psi = low + (u < (r - low)).astype(jnp.float32)
    q = (jnp.sign(xf) * psi).astype(jnp.int8)
    return q, scale


def squant_decode(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _omega_row(row_len: int, s: int) -> float:
    return min(row_len / s**2, float(np.sqrt(row_len)) / s)


def default_alpha(params: PyTree, s: int) -> float:
    """1 / (2 (omega_max + 1)) over leaves (Thm 1 condition)."""
    rows = max(int(l.shape[-1]) if l.ndim else 1 for l in jax.tree.leaves(params))
    return float(1.0 / (2.0 * (_omega_row(rows, s) + 1.0)))


# ---------------------------------------------------------------------------
# Artemis aggregation (runs INSIDE the worker-manual shard_map)
# ---------------------------------------------------------------------------

class ArtemisDistState(NamedTuple):
    h: PyTree        # per-worker memories; leaves [W, ...] (worker-sharded)
    hbar: PyTree     # replicated server memory; leaves [...]
    e: PyTree        # per-worker EF buffers [W, ...] (Dore; zeros-scalar if off)
    acc: PyTree      # per-worker local grad accumulator [W, ...] (local_steps>1)
    step: jax.Array


def init_dist_state(cfg: Optional["DistConfig"], params: PyTree,
                    n_workers: int = 1) -> ArtemisDistState:
    def full(dt):
        return jax.tree.map(lambda p: jnp.zeros((n_workers,) + p.shape, dt),
                            params)

    def stub():
        return jax.tree.map(lambda p: jnp.zeros((n_workers,), jnp.float32),
                            params)

    if cfg is not None and cfg.memory:
        mdt = jnp.dtype(cfg.memory_dtype)
        h = full(mdt)
        hbar = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    else:
        h = stub()
        hbar = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    e = full(jnp.float32) if (cfg is not None and cfg.use_ef) else stub()
    acc = full(jnp.float32) if (cfg is not None and cfg.local_steps > 1) else stub()
    return ArtemisDistState(h=h, hbar=hbar, e=e, acc=acc,
                            step=jnp.zeros((), jnp.int32))


def artemis_aggregate(cfg: DistConfig, state: ArtemisDistState, grads: PyTree,
                      n_workers: int, wid: jax.Array,
                      grad_specs: Optional[PyTree] = None):
    """Per-worker grads -> (descent direction, new state). Inside shard_map,
    where each h leaf is the local [1, ...] slice.

    grad_specs: optional tree of PartitionSpecs (auto axes only) matching
    grads — WITHOUT it GSPMD tends to replicate the int8 payload before the
    inter-worker all-gather, inflating collective bytes ~256x (measured; see
    EXPERIMENTS.md §Perf iteration 1)."""
    axes = cfg.worker_axes
    n = n_workers
    base = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), state.step)
    up_key = jax.random.fold_in(base, wid + 1)     # distinct per worker
    dwn_key = jax.random.fold_in(base, 0)          # SHARED across workers
    alpha = cfg.alpha if cfg.alpha is not None else (
        default_alpha(grads, cfg.s) if cfg.memory else 0.0)

    # partial participation (PP2): Bernoulli mask per worker per step
    if cfg.p_participation < 1.0:
        act_key = jax.random.fold_in(jax.random.fold_in(base, 999), wid)
        active = (jax.random.uniform(act_key, ()) < cfg.p_participation
                  ).astype(jnp.float32)
    else:
        active = jnp.float32(1.0)

    leaves, treedef = jax.tree.flatten(grads)
    h_l = treedef.flatten_up_to(state.h)
    hbar_l = treedef.flatten_up_to(state.hbar)
    e_l = treedef.flatten_up_to(state.e)
    spec_l = (treedef.flatten_up_to(grad_specs) if grad_specs is not None
              else [None] * len(leaves))
    p = cfg.p_participation

    def _pin(x, spec, extra_lead=0):
        if spec is None:
            return x
        full = P(*(((),) * extra_lead + tuple(spec)[:x.ndim - extra_lead]
                   + (None,) * max(0, x.ndim - extra_lead - len(tuple(spec)))))
        return jax.lax.with_sharding_constraint(x, full)

    def _pin_rows(x, spec):
        # scale has the last dim collapsed to 1 -> drop its sharding
        if spec is None:
            return x
        t = tuple(spec)[:x.ndim]
        t = t[:-1] + (None,) if t else t
        return jax.lax.with_sharding_constraint(
            x, P(*(t + (None,) * (x.ndim - len(t)))))

    mdt = jnp.dtype(cfg.memory_dtype)
    out_agg, out_h, out_hbar, out_e = [], [], [], []
    for i, g in enumerate(leaves):
        g32 = g.astype(jnp.float32)
        h = h_l[i][0].astype(jnp.float32) if cfg.memory else jnp.zeros_like(g32)
        e_buf = e_l[i][0] if cfg.use_ef else None
        delta = (g32 - h) * active
        if cfg.use_ef:
            delta = delta + e_buf
        if cfg.up_compress:
            q, scale = squant_encode(jax.random.fold_in(up_key, i), delta, cfg.s)
            q = _pin(q, spec_l[i])
            scale = _pin_rows(scale, spec_l[i])
            # ---- the actual wire: an int8 ring. all_gather over a manual
            # axis forces replication of the auto-sharded dims (measured
            # 256x byte blowup); collective-permute keeps each hop at
            # exactly one int8 shard, so the ring is N-1 shard-sized hops.
            perm = [(j, (j + 1) % n) for j in range(n)]
            dhat_sum = squant_decode(q, scale)
            qr, sr = q, scale
            for _ in range(n - 1):
                qr = jax.lax.ppermute(qr, axes, perm)
                sr = jax.lax.ppermute(sr, axes, perm)
                dhat_sum = dhat_sum + squant_decode(qr, sr)
            dhat_sum = _pin(dhat_sum, spec_l[i])
            dhat_i = squant_decode(q, scale) * active
        else:
            dhat_sum = jax.lax.psum(delta, axes)
            dhat_i = delta
        if cfg.use_ef:
            # EF accumulates what compression lost (Dore-style)
            out_e.append((active * (delta - dhat_i)
                          + (1 - active) * e_buf)[None])
        else:
            out_e.append(e_l[i])
        if cfg.memory:
            hbar = hbar_l[i].astype(jnp.float32)
            ghat = hbar + dhat_sum / (p * n)
            out_h.append((h + alpha * dhat_i).astype(mdt)[None])
            out_hbar.append((hbar + alpha * dhat_sum / n).astype(mdt))
        else:
            ghat = dhat_sum / (p * n)
            out_h.append(h_l[i])
            out_hbar.append(hbar_l[i])
        if cfg.dwn_compress:
            # zero-byte broadcast: identical key -> identical compression
            qd, sd = squant_encode(jax.random.fold_in(dwn_key, i), ghat, cfg.s)
            ghat = squant_decode(qd, sd)
        out_agg.append(ghat.astype(g.dtype))

    agg = jax.tree.unflatten(treedef, out_agg)
    new_state = ArtemisDistState(jax.tree.unflatten(treedef, out_h),
                                 jax.tree.unflatten(treedef, out_hbar),
                                 jax.tree.unflatten(treedef, out_e),
                                 state.acc, state.step + 1)
    return agg, new_state


# ---------------------------------------------------------------------------
# Train-step factory
# ---------------------------------------------------------------------------

class TrainState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    artemis: ArtemisDistState
    step: jax.Array


def _mesh_axis_sizes(mesh: Mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def state_specs(dcfg: Optional[DistConfig], state_struct: TrainState) -> TrainState:
    """Worker-axis PartitionSpecs for shard_map in/out (manual axes only)."""
    waxes = dcfg.worker_axes if dcfg else ()
    rep = P()
    art = ArtemisDistState(
        h=jax.tree.map(lambda _: P(waxes), state_struct.artemis.h),
        hbar=jax.tree.map(lambda _: rep, state_struct.artemis.hbar),
        e=jax.tree.map(lambda _: P(waxes), state_struct.artemis.e),
        acc=jax.tree.map(lambda _: P(waxes), state_struct.artemis.acc),
        step=rep)
    return TrainState(
        params=jax.tree.map(lambda _: rep, state_struct.params),
        opt_state=jax.tree.map(lambda _: rep, state_struct.opt_state),
        artemis=art, step=rep)


def make_local_step(model, dcfg: DistConfig, mesh: Mesh):
    """Accumulate-only step for ``local_steps > 1`` (Remark 2 / Local-SGD
    direction, realized as gradient accumulation so params stay replicated):
    run this k-1 times between make_train_step's communicating step. ZERO
    inter-worker collectives in its HLO — the roofline-visible comm saving.
    """
    waxes = dcfg.worker_axes

    def local_fn(state: TrainState, batch):
        sspec = state_specs(dcfg, state)
        bspec = jax.tree.map(lambda _: P(waxes), batch)
        mspec = {"nll": P(), "aux": P()}

        @functools.partial(
            jax.shard_map, mesh=mesh, in_specs=(sspec, bspec),
            out_specs=(sspec, (P(), mspec)), axis_names=set(waxes),
            check_vma=False)
        def inner(st: TrainState, bt):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(st.params, bt)
            acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype)[None],
                               st.artemis.acc, grads)
            return (st._replace(artemis=st.artemis._replace(acc=acc)),
                    (loss, metrics))

        return inner(state, batch)

    return local_fn


def make_train_step(model, optimizer, dcfg: Optional[DistConfig], mesh: Mesh,
                    grad_specs: Optional[PyTree] = None):
    """Build (init_state_fn, step_fn).

    dcfg=None   -> plain data-parallel baseline (jit only; XLA aggregates).
    dcfg given  -> Artemis over dcfg.worker_axes via partial-manual shard_map.
    grad_specs  -> PartitionSpec tree (auto axes only) pinning the compressed
                   payload sharding inside the aggregation (strongly
                   recommended at scale; see artemis_aggregate).
    """
    sizes = _mesh_axis_sizes(mesh)
    n_workers = 1
    if dcfg:
        for a in dcfg.worker_axes:
            n_workers *= sizes[a]

    def init_state(params) -> TrainState:
        opt_state = optimizer.init(params)
        art = init_dist_state(dcfg, params, n_workers)
        return TrainState(params, opt_state, art, jnp.zeros((), jnp.int32))

    k_local = dcfg.local_steps if dcfg else 1

    def sgd_core(params, opt_state, art, stepno, batch, wid):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        if k_local > 1:
            # fold in the locally-accumulated gradients since the last sync
            grads = jax.tree.map(lambda a, g: (a[0] + g) / k_local,
                                 art.acc, grads)
            art = art._replace(acc=jax.tree.map(
                lambda a: jnp.zeros_like(a), art.acc))
        if dcfg is not None and dcfg.worker_axes:
            agg, art = artemis_aggregate(dcfg, art, grads, n_workers, wid,
                                         grad_specs)
        else:
            agg = grads
            art = art._replace(step=art.step + 1)
        updates, opt_state = optimizer.update(agg, opt_state, stepno)
        params = jax.tree.map(lambda pp, u: (pp - u.astype(pp.dtype)).astype(pp.dtype),
                              params, updates)
        return params, opt_state, art, loss, metrics

    if dcfg is None or not dcfg.worker_axes:
        def step_fn(state: TrainState, batch):
            params, opt_state, art, loss, metrics = sgd_core(
                state.params, state.opt_state, state.artemis, state.step,
                batch, jnp.zeros((), jnp.int32))
            return (TrainState(params, opt_state, art, state.step + 1),
                    (loss, metrics))
        return init_state, step_fn

    waxes = dcfg.worker_axes
    strides = {}
    acc = 1
    for a in reversed(waxes):
        strides[a] = acc
        acc *= sizes[a]

    def step_fn(state: TrainState, batch):
        sspec = state_specs(dcfg, state)
        bspec = jax.tree.map(lambda _: P(waxes), batch)
        mspec = {"nll": P(), "aux": P()}

        # check_vma=False: replication of params/hbar across workers holds by
        # construction (aggregate is psum'd; downlink uses a shared PRNG key),
        # but vma tracking cannot see through it (literal scan carries inside
        # the model would all need manual pvary casts).
        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(sspec, bspec),
            out_specs=(sspec, (P(), mspec)),
            axis_names=set(waxes), check_vma=False)
        def inner(st: TrainState, bt):
            wid = jnp.zeros((), jnp.int32)
            for a in waxes:
                wid = wid + jax.lax.axis_index(a) * strides[a]
            params, opt_state, art, loss, metrics = sgd_core(
                st.params, st.opt_state, st.artemis, st.step, bt, wid)
            loss = jax.lax.pmean(loss, waxes)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, waxes), metrics)
            return (TrainState(params, opt_state, art, st.step + 1),
                    (loss, metrics))

        return inner(state, batch)

    return init_state, step_fn
