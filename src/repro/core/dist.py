"""Mesh-distributed Artemis: compressed gradient aggregation over a worker axis.

Workers are slices of the device mesh along ``worker_axes`` (the 'pod' axis on
the production multi-pod mesh: the slow DCN inter-pod links play the paper's
bandwidth-constrained uplink/downlink).  The train step is wrapped in a
*partial-manual* ``shard_map``: worker axes are manual — so ``jax.grad``
inside yields the per-worker gradient, un-psum'd — while the remaining
data/model axes stay auto, letting GSPMD shard the model inside each worker
exactly as in the uncompressed baseline.

Wire layer (``wire="bucketed"``, the default — DESIGN.md §7): the gradient
pytree is flattened into <= K equal byte-size f32 buckets
(``core/bucketing.py``), each bucket squant-encoded into one contiguous
``int8 levels + f32 row-scales`` payload, and the payloads move around a
**pipelined double-buffered ring**: inside a ``lax.scan`` over the N-1 hops,
hop j's ``ppermute`` of the stacked bucket payload is issued while hop j-1's
payload is dequant-accumulated by ``kernels/bucket_ring.py`` — the carry
holds the in-flight payload, so on real hardware the dequant hides under the
wire latency and the step is bandwidth- (not latency-) bound.  The legacy
``wire="leaf"`` path keeps the seed's one-ring-per-leaf schedule (N-1
*sequential* hops per leaf) as the benchmark baseline.

Wire format is real either way: the uplink moves **int8 levels + per-row f32
scales** across workers (visible in compiled HLO as s8 collective-permutes —
``launch/roofline.bucketed_wire_model`` predicts the bytes and
``tests/helpers/bucket_scenarios.py::hlo_wire_guard`` pins them in CI).  The
downlink broadcast costs ZERO bytes: every worker compresses the identical
aggregate with an identical PRNG key (the TPU-native replacement for the
server->worker broadcast).

State per paper Algorithm 1 (PP2):
  h    — per-worker memory h_i; bucketed: one [W, B, R, C] stack sharded
         over the worker axes (leaf wire: per-leaf [W, ...] trees).
  hbar — server memory \bar h; replicated (every worker updates it with the
         same summed quantity, so it stays bitwise identical).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import bucketing
from repro.core import codec as wire
from repro.core import faults as FLT
from repro.kernels import bucket_ring as BK
from repro.kernels import default_interpret
from repro.obs import telemetry as obs_tel

PyTree = Any

VARIANTS = ("sgd", "qsgd", "diana", "biqsgd", "artemis", "dore")

WIRES = ("bucketed", "leaf")
REDUCE_IMPLS = ("pipelined", "sequential", "psum")


# ---------------------------------------------------------------------------
# shard_map compatibility (new jax.shard_map API vs jax<=0.4 experimental)
# ---------------------------------------------------------------------------

def shard_map_compat(f, mesh: Mesh, in_specs, out_specs,
                     manual_axes: Sequence[str]):
    """Partial-manual shard_map on either jax API generation.

    New API: ``jax.shard_map(axis_names=..., check_vma=False)`` — replication
    of params/hbar across workers holds by construction (aggregate is summed
    identically; downlink uses a shared PRNG key) but vma tracking cannot see
    through it.  Old API (jax<=0.4.x): ``jax.experimental.shard_map`` with
    ``auto = mesh axes - manual`` and ``check_rep=False`` (same reasoning).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(manual_axes), check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def make_worker_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Worker-only mesh that works on both jax API generations (tests and
    benchmarks simulate multi-host rings with fake CPU devices)."""
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    worker_axes: Tuple[str, ...] = ("pod",)
    variant: str = "artemis"
    s: int = 1                      # quantization levels
    alpha: Optional[float] = None   # None -> 1/(2(omega+1)), omega = sqrt(row)/s
    p_participation: float = 1.0    # PP2 over workers when < 1
    memory_dtype: str = "float32"   # h storage dtype (bf16 = beyond-paper)
    error_feedback: bool = False    # Dore-style EF on the uplink (beyond paper)
    local_steps: int = 1            # communicate every k steps (Remark 2 /
                                    # Local-SGD direction; 1 = every step)
    seed: int = 17
    # --- wire layer (DESIGN.md §7) ---
    wire: str = "bucketed"          # "bucketed" flat ring | legacy "leaf" loop
    bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES
    max_buckets: int = bucketing.DEFAULT_MAX_BUCKETS
    bucket_row: int = bucketing.DEFAULT_ROW      # per-row-scale tile C
    reduce_impl: str = "pipelined"  # "pipelined" scan ring | "sequential"
                                    # unrolled hops | "psum" dense reference
    # --- wire codec (core/codec.py registry; DESIGN.md §9) ---
    codec: str = "squant"           # "squant" = the native row-scale wire
                                    # format; any registered codec works
    codec_kwargs: Tuple[Tuple[str, Any], ...] = ()
    # --- fault injection + server defenses (core/faults.py, DESIGN.md §8) ---
    faults: Optional[FLT.FaultConfig] = None
    # --- observability (repro.obs, DESIGN.md §11) ---
    # STATIC gate: False builds the byte-identical legacy step.  True makes
    # the aggregates return a third `obs` dict (repro.obs.telemetry
    # MESH_METRICS: physical wire bytes/step reconciled against the
    # launch/roofline models, participation, scrub/blowup counts) which the
    # train step psums over workers and attaches to the step metrics under
    # "obs".  All values are computed from quantities the step already has —
    # no extra collectives beyond the psums of four scalars.
    telemetry: bool = False

    def __post_init__(self):
        if self.wire not in WIRES:
            raise ValueError(f"wire={self.wire!r} not in {WIRES}")
        if self.reduce_impl not in REDUCE_IMPLS:
            raise ValueError(
                f"reduce_impl={self.reduce_impl!r} not in {REDUCE_IMPLS}")
        name = {"squant": "row_squant"}.get(self.codec, self.codec)
        if name not in wire.available():
            raise ValueError(
                f"codec={self.codec!r} not in {wire.available()}")

    @property
    def up_compress(self) -> bool:
        return self.variant in ("qsgd", "diana", "biqsgd", "artemis", "dore")

    @property
    def dwn_compress(self) -> bool:
        return self.variant in ("biqsgd", "artemis", "dore")

    @property
    def memory(self) -> bool:
        return self.variant in ("diana", "artemis", "dore")

    @property
    def use_ef(self) -> bool:
        return self.error_feedback or self.variant == "dore"

    @property
    def bucketed(self) -> bool:
        return self.wire == "bucketed"

    def layout(self, tree: PyTree) -> bucketing.BucketLayout:
        return bucketing.make_layout(tree, bucket_bytes=self.bucket_bytes,
                                     max_buckets=self.max_buckets,
                                     row=self.bucket_row)

    def wire_codec(self, row: int) -> wire.Codec:
        """The codec that runs on this wire for messages with last-axis
        length ``row`` (which fixes omega).  ``codec="squant"`` maps to the
        native per-row-scale mesh format ``row_squant``."""
        name = {"squant": "row_squant"}.get(self.codec, self.codec)
        kw = dict(self.codec_kwargs)
        if name == "row_squant":
            kw.setdefault("s", self.s)
        return wire.make_codec(name, row, **kw)


# ---------------------------------------------------------------------------
# distributed-friendly per-row s-quantization (sharding-transparent)
# ---------------------------------------------------------------------------

# The row-scale wire format now lives in core/codec.py ("row_squant") so the
# kernels, the mesh wires, and the simulator share one definition; these
# aliases keep the historical dist-level entry points.
squant_encode = wire.row_squant_encode
squant_decode = wire.row_squant_decode


def _omega_row(row_len: int, s: int) -> float:
    return wire.squant_omega(row_len, s)


def default_alpha(params: PyTree, s: int) -> float:
    """1 / (2 (omega_max + 1)) over leaves (Thm 1 condition)."""
    rows = max(int(l.shape[-1]) if l.ndim else 1 for l in jax.tree.leaves(params))
    return float(1.0 / (2.0 * (_omega_row(rows, s) + 1.0)))


def default_alpha_bucketed(row: int, s: int) -> float:
    """Thm 1 alpha for the bucketed wire: every row has length ``row``."""
    return float(1.0 / (2.0 * (_omega_row(row, s) + 1.0)))


def _codec_alpha(cfg: "DistConfig", rows) -> float:
    """Thm 1 alpha from the wire codec's omega (max over message rows).
    For the native squant wire this equals ``default_alpha*`` bit-for-bit
    (same doubles through the same formula)."""
    om = max(cfg.wire_codec(int(r)).omega for r in rows)
    return float(1.0 / (2.0 * (om + 1.0)))


def _payload_nbytes(payload) -> float:
    """Byte size of one encoded wire payload.  Shapes are static at trace
    time, so this is a Python constant — the telemetry wire-byte counter
    costs nothing in the compiled step.  Equals the codec's declared
    ``wire_bytes`` split summed over dtypes (the encoders ship exactly the
    arrays they declare; the HLO wire guard pins that equivalence)."""
    return float(sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                     for l in jax.tree.leaves(payload)))


# ---------------------------------------------------------------------------
# bucketed ring transports (run INSIDE the worker-manual shard_map)
# ---------------------------------------------------------------------------

def bucket_encode(key: jax.Array, buckets: jax.Array, s: int):
    """Per-bucket squant encode: [B, R, C] -> (q int8 [B,R,C], scales
    [B,R,1] f32), one PRNG key per bucket (``bucketing.bucket_keys``).
    Kept for benchmarks/tests; the aggregate now goes through
    ``bucketing.encode_buckets`` with an arbitrary codec."""
    keys = bucketing.bucket_keys(key, buckets.shape[0])
    return jax.vmap(lambda k, x: squant_encode(k, x, s))(keys, buckets)


def payload_decode(codec: wire.Codec, payload: wire.WirePayload) -> jax.Array:
    """Decode a bucket-stacked payload (leaves carry a leading B axis)."""
    return jax.vmap(codec.decode)(payload)


def _payload_acc(codec: wire.Codec, acc: jax.Array,
                 payload: wire.WirePayload, interpret: bool) -> jax.Array:
    """One dequant-accumulate: the native row-scale payload rides the fused
    kernels/bucket_ring path; any other codec decodes then adds."""
    if codec.fused_acc:
        return BK.bucket_acc(acc, payload["levels"], payload["scales"],
                             interpret=interpret)
    return acc + payload_decode(codec, payload)


def bucket_ring_reduce(codec: wire.Codec, payload: wire.WirePayload,
                       axes: Tuple[str, ...], n: int, *,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Pipelined double-buffered ring all-reduce of compressed payloads.

    ``lax.scan`` over the N-1 hops; the carry holds the in-flight payload
    (a codec ``WirePayload`` pytree — every leaf gets its own ``ppermute``).
    Each hop issues the next ``ppermute`` *and* dequant-accumulates the
    payload it currently holds — the two are data-independent inside the
    step, so the compiler overlaps the collective with the compute (comm
    hides under dequant or vice versa).  Accumulation order (own payload
    first, then arrivals from w-1, w-2, ...) matches the sequential
    transport bit-for-bit.
    """
    itp = default_interpret() if interpret is None else interpret
    acc = jnp.zeros(jax.eval_shape(lambda p: payload_decode(codec, p),
                                   payload).shape, jnp.float32)
    if n == 1:
        return _payload_acc(codec, acc, payload, itp)
    perm = [(j, (j + 1) % n) for j in range(n)]

    def hop(carry, _):
        pc, a = carry
        # named_scope: metadata-only annotation so the hop's ppermute +
        # dequant-accumulate are findable on the profiler timeline
        with jax.named_scope("ring_hop"):
            pn = jax.tree.map(lambda l: jax.lax.ppermute(l, axes, perm), pc)
            a = _payload_acc(codec, a, pc, itp)
        return (pn, a), None

    (pl, acc), _ = jax.lax.scan(hop, (payload, acc), None, length=n - 1)
    return _payload_acc(codec, acc, pl, itp)


def bucket_ring_reduce_sequential(codec: wire.Codec,
                                  payload: wire.WirePayload,
                                  axes: Tuple[str, ...], n: int) -> jax.Array:
    """The pre-bucketing transport applied to the bucket payload: N-1
    *blocking* hops with a dequant-accumulate stall between each (the
    per-leaf ring of ``wire="leaf"``, kept as the pipelining baseline)."""
    acc = payload_decode(codec, payload)
    if n == 1:
        return acc
    perm = [(j, (j + 1) % n) for j in range(n)]
    pr = payload
    for _ in range(n - 1):
        pr = jax.tree.map(lambda l: jax.lax.ppermute(l, axes, perm), pr)
        acc = acc + payload_decode(codec, pr)
    return acc


# ---------------------------------------------------------------------------
# Artemis aggregation (runs INSIDE the worker-manual shard_map)
# ---------------------------------------------------------------------------

class ArtemisDistState(NamedTuple):
    h: PyTree        # per-worker memories; bucketed [W, B, R, C] stack
    hbar: PyTree     # replicated server memory; bucketed [B, R, C]
    e: PyTree        # per-worker EF buffers (Dore; zeros-scalar stub if off)
    acc: PyTree      # per-worker local grad accumulator (local_steps > 1)
    prev_active: jax.Array  # [W] last-round availability (Markov chain state)
    step: jax.Array


def init_dist_state(cfg: Optional["DistConfig"], params: PyTree,
                    n_workers: int = 1) -> ArtemisDistState:
    if cfg is not None and cfg.bucketed:
        shape = cfg.layout(params).shape

        def full(dt):
            return jnp.zeros((n_workers,) + shape, dt)

        def stub():
            return jnp.zeros((n_workers,), jnp.float32)

        if cfg.memory:
            mdt = jnp.dtype(cfg.memory_dtype)
            h, hbar = full(mdt), jnp.zeros(shape, mdt)
        else:
            h, hbar = stub(), jnp.zeros((), jnp.float32)
        e = full(jnp.float32) if cfg.use_ef else stub()
        acc = full(jnp.float32) if cfg.local_steps > 1 else stub()
        return ArtemisDistState(h=h, hbar=hbar, e=e, acc=acc,
                                prev_active=jnp.zeros((n_workers,),
                                                      jnp.float32),
                                step=jnp.zeros((), jnp.int32))

    def full(dt):
        return jax.tree.map(lambda p: jnp.zeros((n_workers,) + p.shape, dt),
                            params)

    def stub():
        return jax.tree.map(lambda p: jnp.zeros((n_workers,), jnp.float32),
                            params)

    if cfg is not None and cfg.memory:
        mdt = jnp.dtype(cfg.memory_dtype)
        h = full(mdt)
        hbar = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    else:
        h = stub()
        hbar = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    e = full(jnp.float32) if (cfg is not None and cfg.use_ef) else stub()
    acc = full(jnp.float32) if (cfg is not None and cfg.local_steps > 1) else stub()
    return ArtemisDistState(h=h, hbar=hbar, e=e, acc=acc,
                            prev_active=jnp.zeros((n_workers,), jnp.float32),
                            step=jnp.zeros((), jnp.int32))


def _round_keys(cfg: DistConfig, step: jax.Array, wid: jax.Array,
                prev: jax.Array):
    """(uplink key — distinct per worker, downlink key — SHARED, active mask,
    availability, fault key).

    Shared by the leaf and bucketed paths so switching the wire never changes
    the participation pattern or the downlink stream.  ``prev`` is this
    worker's last-round availability (the Markov chain state); ``part`` is
    this round's availability BEFORE stragglers drop out — the chain evolves
    on availability, not on who made the deadline."""
    fc = FLT.of(cfg.faults)
    base = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    up_key = jax.random.fold_in(base, wid + 1)
    dwn_key = jax.random.fold_in(base, 0)
    # separate salted stream: the base up/dwn/participation draws never move
    flt_key = jax.random.fold_in(jax.random.fold_in(base, FLT.FAULT_SALT), wid)
    if cfg.p_participation < 1.0 or fc.markov:
        act_key = jax.random.fold_in(jax.random.fold_in(base, 999), wid)
        u = jax.random.uniform(act_key, ())
        part = FLT.participation(fc, cfg.p_participation, u, prev, step)
    else:
        part = jnp.float32(1.0)
    active = part
    if fc.straggler_rate > 0.0:
        u_s = jax.random.uniform(jax.random.fold_in(flt_key, 1), ())
        active = active * (u_s >= fc.straggler_rate).astype(jnp.float32)
    return up_key, dwn_key, active, part, flt_key


def artemis_aggregate_bucketed(cfg: DistConfig, state: ArtemisDistState,
                               gbuckets: jax.Array,
                               layout: bucketing.BucketLayout,
                               n_workers: int, wid: jax.Array):
    """Bucketed per-worker grads [B, R, C] -> (descent buckets, new state).

    Inside shard_map, where each per-worker state leaf is the local
    [1, B, R, C] slice.  The uplink sum runs over ``cfg.reduce_impl``:
    the pipelined scan ring (default), the sequential unrolled ring (the
    pre-bucketing schedule — bit-identical result), or a dense
    dequantize-then-psum (the equivalence-test reference).
    """
    axes = cfg.worker_axes
    n = n_workers
    fc = FLT.of(cfg.faults)
    wc = cfg.wire_codec(layout.row)
    up_key, dwn_key, active, part, flt_key = _round_keys(
        cfg, state.step, wid, state.prev_active[0])
    alpha = cfg.alpha if cfg.alpha is not None else (
        _codec_alpha(cfg, [layout.row]) if cfg.memory else 0.0)
    p = cfg.p_participation
    mdt = jnp.dtype(cfg.memory_dtype)

    obs_blow = jnp.zeros((), jnp.float32)
    obs_scrub = jnp.zeros((), jnp.float32)
    obs_bytes = 0.0

    g32 = gbuckets.astype(jnp.float32)
    if fc.blowup_rate > 0.0:
        hit = jax.random.bernoulli(jax.random.fold_in(flt_key, 2),
                                   fc.blowup_rate, ())
        g32 = jnp.where(hit, jnp.float32(fc.blowup_value), g32)
        if cfg.telemetry:
            obs_blow = hit.astype(jnp.float32)
    if fc.scrub:
        # non-finite local gradient => worker masked inactive BEFORE any
        # arithmetic (0 * NaN is NaN, so the rows are zeroed too)
        finite = jnp.all(jnp.isfinite(g32)).astype(jnp.float32)
        active = active * finite
        g32 = FLT.nan_to_zero(g32)
    h = state.h[0].astype(jnp.float32) if cfg.memory else jnp.zeros_like(g32)
    e_buf = state.e[0] if cfg.use_ef else None
    delta = (g32 - h) * active
    if cfg.use_ef:
        delta = delta + e_buf

    ok = active
    if cfg.up_compress:
        enc = bucketing.encode_buckets(wc, up_key, delta)
        # PP2: an inactive worker's payload (its EF buffer under Dore) must
        # contribute EXACTLY zero to the sum — zero the wire float leaves
        # (the scales for squant, the values for sparsify).
        enc = FLT.mask_payload(enc, active)
        if fc.bitflip_rate > 0.0:
            # only a payload actually on the wire can pick up flipped bits
            enc = FLT.corrupt_payload(jax.random.fold_in(flt_key, 3), enc,
                                      fc.bitflip_rate, only=active)
        if fc.scrub:
            # per-BUCKET checksum: a corrupt bucket is dropped through the
            # same zero-scale path as inactivity; its h/e slices stay put
            valid = jax.vmap(wc.validate)(enc)         # [B]
            ok = active * valid.reshape(-1, 1, 1)      # [B,1,1] broadcast
            enc = FLT.scrub_payload(enc, valid)
            if cfg.telemetry:
                obs_scrub = valid.shape[0] - jnp.sum(valid)
        if cfg.reduce_impl == "psum":
            dhat_sum = jax.lax.psum(payload_decode(wc, enc), axes)
            # all-reduce proxy: result bytes ~ bytes sent per device on a
            # ring (the same convention launch/roofline uses)
            obs_bytes = 4.0 * float(np.prod(g32.shape))
        elif cfg.reduce_impl == "sequential":
            dhat_sum = bucket_ring_reduce_sequential(wc, enc, axes, n)
            obs_bytes = (n - 1) * _payload_nbytes(enc)
        else:
            dhat_sum = bucket_ring_reduce(wc, enc, axes, n)
            obs_bytes = (n - 1) * _payload_nbytes(enc)
        dhat_i = payload_decode(wc, enc)
    else:
        dhat_i = delta * active
        dhat_sum = jax.lax.psum(dhat_i, axes)
        obs_bytes = 4.0 * float(np.prod(g32.shape))

    if cfg.use_ef:
        e_new = (ok * (delta - dhat_i) + (1 - ok) * e_buf)[None]
    else:
        e_new = state.e
    if cfg.memory:
        hbar = state.hbar.astype(jnp.float32)
        ghat = hbar + dhat_sum / (p * n)
        h_new = (h + alpha * dhat_i).astype(mdt)[None]
        hbar_new = (hbar + alpha * dhat_sum / n).astype(mdt)
    else:
        ghat = dhat_sum / (p * n)
        h_new, hbar_new = state.h, state.hbar
    if cfg.dwn_compress:
        # zero-byte broadcast: identical key -> identical compression
        ghat = payload_decode(wc, bucketing.encode_buckets(wc, dwn_key, ghat))

    new_state = ArtemisDistState(h_new, hbar_new, e_new, state.acc,
                                 jnp.reshape(part, (1,)), state.step + 1)
    if not cfg.telemetry:
        return ghat, new_state
    obs = {"wire_bytes": jnp.float32(obs_bytes),
           "mesh_active": jnp.reshape(active, ()).astype(jnp.float32),
           "mesh_scrubbed": obs_scrub.astype(jnp.float32),
           "mesh_blowup_hits": obs_blow}
    return ghat, new_state, obs


def artemis_aggregate(cfg: DistConfig, state: ArtemisDistState, grads: PyTree,
                      n_workers: int, wid: jax.Array,
                      grad_specs: Optional[PyTree] = None):
    """Legacy leaf wire: per-worker grads -> (descent direction, new state).
    One int8 ring per pytree leaf, N-1 sequential hops each.  Inside
    shard_map, where each h leaf is the local [1, ...] slice.

    grad_specs: optional tree of PartitionSpecs (auto axes only) matching
    grads — WITHOUT it GSPMD tends to replicate the int8 payload before the
    inter-worker all-gather, inflating collective bytes ~256x (measured; see
    EXPERIMENTS.md §Perf iteration 1)."""
    axes = cfg.worker_axes
    n = n_workers
    fc = FLT.of(cfg.faults)
    up_key, dwn_key, active, part, flt_key = _round_keys(
        cfg, state.step, wid, state.prev_active[0])
    if fc.blowup_rate > 0.0:
        blow_hit = jax.random.bernoulli(jax.random.fold_in(flt_key, 2),
                                        fc.blowup_rate, ())
    leaf_rows = [int(l.shape[-1]) if l.ndim else 1
                 for l in jax.tree.leaves(grads)]
    alpha = cfg.alpha if cfg.alpha is not None else (
        _codec_alpha(cfg, leaf_rows) if cfg.memory else 0.0)

    leaves, treedef = jax.tree.flatten(grads)
    h_l = treedef.flatten_up_to(state.h)
    hbar_l = treedef.flatten_up_to(state.hbar)
    e_l = treedef.flatten_up_to(state.e)
    spec_l = (treedef.flatten_up_to(grad_specs) if grad_specs is not None
              else [None] * len(leaves))
    p = cfg.p_participation

    def _pin(x, spec, extra_lead=0):
        if spec is None:
            return x
        full = P(*(((),) * extra_lead + tuple(spec)[:x.ndim - extra_lead]
                   + (None,) * max(0, x.ndim - extra_lead - len(tuple(spec)))))
        return jax.lax.with_sharding_constraint(x, full)

    def _pin_rows(x, spec):
        # scale has the last dim collapsed to 1 -> drop its sharding
        if spec is None:
            return x
        t = tuple(spec)[:x.ndim]
        t = t[:-1] + (None,) if t else t
        return jax.lax.with_sharding_constraint(
            x, P(*(t + (None,) * (x.ndim - len(t)))))

    mdt = jnp.dtype(cfg.memory_dtype)
    obs_blow = jnp.zeros((), jnp.float32)
    if cfg.telemetry and fc.blowup_rate > 0.0:
        obs_blow = blow_hit.astype(jnp.float32)
    obs_scrub = jnp.zeros((), jnp.float32)
    obs_bytes = 0.0
    out_agg, out_h, out_hbar, out_e = [], [], [], []
    for i, g in enumerate(leaves):
        g32 = g.astype(jnp.float32)
        act_l = active
        if fc.blowup_rate > 0.0:
            g32 = jnp.where(blow_hit, jnp.float32(fc.blowup_value), g32)
        if fc.scrub:
            # non-finite leaf => this worker sits the leaf's ring out
            finite = jnp.all(jnp.isfinite(g32)).astype(jnp.float32)
            act_l = act_l * finite
            g32 = FLT.nan_to_zero(g32)
        h = h_l[i][0].astype(jnp.float32) if cfg.memory else jnp.zeros_like(g32)
        e_buf = e_l[i][0] if cfg.use_ef else None
        delta = (g32 - h) * act_l
        if cfg.use_ef:
            delta = delta + e_buf
        ok_l = act_l
        if cfg.up_compress:
            wcl = cfg.wire_codec(int(g.shape[-1]) if g.ndim else 1)
            p_l = wcl.encode(jax.random.fold_in(up_key, i), delta)
            # PP2: an inactive worker's payload (its EF buffer under Dore)
            # must contribute EXACTLY zero to the ring sum — zero the wire
            # float leaves (the scales for squant).
            p_l = FLT.mask_payload(p_l, act_l)
            if fc.bitflip_rate > 0.0:
                p_l = FLT.corrupt_payload(jax.random.fold_in(flt_key, 10 + i),
                                          p_l, fc.bitflip_rate, only=act_l)
            if fc.scrub:
                # per-LEAF checksum -> dropped via the zero-scale path
                valid = wcl.validate(p_l)
                ok_l = act_l * valid
                p_l = FLT.scrub_payload(p_l, valid)
                if cfg.telemetry:
                    obs_scrub = obs_scrub + (1.0 - valid)
            if "levels" in p_l.data:
                # levels keep the leaf's auto-axis sharding; scales have the
                # last dim collapsed (other codecs ship 1-D index/value
                # payloads the leaf specs don't apply to)
                p_l = p_l.replace(levels=_pin(p_l["levels"], spec_l[i]),
                                  scales=_pin_rows(p_l["scales"], spec_l[i]))
            # ---- the actual wire: an int8 ring. all_gather over a manual
            # axis forces replication of the auto-sharded dims (measured
            # 256x byte blowup); collective-permute keeps each hop at
            # exactly one int8 shard, so the ring is N-1 shard-sized hops.
            perm = [(j, (j + 1) % n) for j in range(n)]
            dhat_sum = wcl.decode(p_l)
            pr = p_l
            for _ in range(n - 1):
                pr = jax.tree.map(lambda l: jax.lax.ppermute(l, axes, perm),
                                  pr)
                dhat_sum = dhat_sum + wcl.decode(pr)
            dhat_sum = _pin(dhat_sum, spec_l[i])
            dhat_i = wcl.decode(p_l)
            obs_bytes += (n - 1) * _payload_nbytes(p_l)
        else:
            dhat_i = delta * act_l
            dhat_sum = jax.lax.psum(dhat_i, axes)
            obs_bytes += 4.0 * float(np.prod(g.shape) if g.ndim else 1)
        if cfg.use_ef:
            # EF accumulates what compression lost (Dore-style)
            out_e.append((ok_l * (delta - dhat_i)
                          + (1 - ok_l) * e_buf)[None])
        else:
            out_e.append(e_l[i])
        if cfg.memory:
            hbar = hbar_l[i].astype(jnp.float32)
            ghat = hbar + dhat_sum / (p * n)
            out_h.append((h + alpha * dhat_i).astype(mdt)[None])
            out_hbar.append((hbar + alpha * dhat_sum / n).astype(mdt))
        else:
            ghat = dhat_sum / (p * n)
            out_h.append(h_l[i])
            out_hbar.append(hbar_l[i])
        if cfg.dwn_compress:
            # zero-byte broadcast: identical key -> identical compression
            wcd = cfg.wire_codec(int(g.shape[-1]) if g.ndim else 1)
            ghat = wcd.decode(wcd.encode(jax.random.fold_in(dwn_key, i),
                                         ghat))
        out_agg.append(ghat.astype(g.dtype))

    agg = jax.tree.unflatten(treedef, out_agg)
    new_state = ArtemisDistState(jax.tree.unflatten(treedef, out_h),
                                 jax.tree.unflatten(treedef, out_hbar),
                                 jax.tree.unflatten(treedef, out_e),
                                 state.acc, jnp.reshape(part, (1,)),
                                 state.step + 1)
    if not cfg.telemetry:
        return agg, new_state
    obs = {"wire_bytes": jnp.float32(obs_bytes),
           "mesh_active": jnp.reshape(active, ()).astype(jnp.float32),
           "mesh_scrubbed": obs_scrub.astype(jnp.float32),
           "mesh_blowup_hits": obs_blow}
    return agg, new_state, obs


# ---------------------------------------------------------------------------
# Train-step factory
# ---------------------------------------------------------------------------

class TrainState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    artemis: ArtemisDistState
    step: jax.Array


def _mesh_axis_sizes(mesh: Mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def state_specs(dcfg: Optional[DistConfig], state_struct: TrainState) -> TrainState:
    """Worker-axis PartitionSpecs for shard_map in/out (manual axes only)."""
    waxes = dcfg.worker_axes if dcfg else ()
    rep = P()
    art = ArtemisDistState(
        h=jax.tree.map(lambda _: P(waxes), state_struct.artemis.h),
        hbar=jax.tree.map(lambda _: rep, state_struct.artemis.hbar),
        e=jax.tree.map(lambda _: P(waxes), state_struct.artemis.e),
        acc=jax.tree.map(lambda _: P(waxes), state_struct.artemis.acc),
        prev_active=P(waxes),
        step=rep)
    return TrainState(
        params=jax.tree.map(lambda _: rep, state_struct.params),
        opt_state=jax.tree.map(lambda _: rep, state_struct.opt_state),
        artemis=art, step=rep)


def make_local_step(model, dcfg: DistConfig, mesh: Mesh):
    """Accumulate-only step for ``local_steps > 1`` (Remark 2 / Local-SGD
    direction, realized as gradient accumulation so params stay replicated):
    run this k-1 times between make_train_step's communicating step. ZERO
    inter-worker collectives in its HLO — the roofline-visible comm saving.
    (Bucketed wire: the accumulator lives in bucket space, so the
    communicating step folds it in without re-flattening.)
    """
    waxes = dcfg.worker_axes

    def local_fn(state: TrainState, batch):
        sspec = state_specs(dcfg, state)
        bspec = jax.tree.map(lambda _: P(waxes), batch)
        mspec = {"nll": P(), "aux": P()}

        def inner(st: TrainState, bt):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(st.params, bt)
            if dcfg.bucketed:
                gb = bucketing.bucketize(dcfg.layout(grads), grads)
                acc = st.artemis.acc + gb[None]
            else:
                acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype)[None],
                                   st.artemis.acc, grads)
            return (st._replace(artemis=st.artemis._replace(acc=acc)),
                    (loss, metrics))

        return shard_map_compat(inner, mesh, (sspec, bspec),
                                (sspec, (P(), mspec)), waxes)(state, batch)

    return local_fn


def state_shardings(mesh: Mesh, state, pshard, dcfg: Optional[DistConfig]):
    """Shardings for TrainState: params per policy; h gets a leading worker
    dim over worker_axes; hbar like params; opt_state like params.

    These are exactly the shardings ``make_train_step``'s step emits, so a
    state placed on them round-trips through the step without a re-layout —
    and without the silent second XLA compile that a SingleDeviceSharding
    initial state costs (the jaxpr is cached but the executable is keyed on
    arg shardings; the trace audit pins this to one compile).

    Bucketed wire: the artemis leaves are single stacked arrays, not
    per-param trees — h/e/acc carry a leading worker dim ([W, B, R, C] or a
    [W] stub) sharded over the worker axes, hbar ([B, R, C]) is replicated
    (every worker applies the identical summed update)."""
    rep = NamedSharding(mesh, P())
    if dcfg is not None and dcfg.bucketed:
        waxes = dcfg.worker_axes
        wsh = NamedSharding(mesh, P(waxes))
        opt_sh = jax.tree.map(lambda l: rep, state.opt_state) \
            if state.opt_state != () else ()
        return TrainState(
            params=pshard, opt_state=opt_sh,
            artemis=ArtemisDistState(
                h=jax.tree.map(lambda _: wsh, state.artemis.h),
                hbar=jax.tree.map(lambda _: rep, state.artemis.hbar),
                e=jax.tree.map(lambda _: wsh, state.artemis.e),
                acc=jax.tree.map(lambda _: wsh, state.artemis.acc),
                prev_active=wsh,
                step=rep),
            step=rep)

    def shift(ns):
        spec = ns.spec
        waxes = dcfg.worker_axes if dcfg else ()
        return NamedSharding(mesh, P(waxes, *spec))

    def worker_tree(struct_tree, full: bool):
        if full:
            return jax.tree.map(shift, pshard)
        return jax.tree.map(lambda _: rep, struct_tree)

    if dcfg is not None and dcfg.memory:
        h_sh = worker_tree(state.artemis.h, True)
        hbar_sh = jax.tree.map(lambda ns: ns, pshard)
    else:
        h_sh = worker_tree(state.artemis.h, False)
        hbar_sh = jax.tree.map(lambda _: rep, state.artemis.hbar)
    e_sh = worker_tree(state.artemis.e, dcfg is not None and dcfg.use_ef)
    acc_sh = worker_tree(state.artemis.acc,
                         dcfg is not None and dcfg.local_steps > 1)
    opt_sh = jax.tree.map(lambda l: rep, state.opt_state) \
        if state.opt_state != () else ()
    waxes_sh = NamedSharding(mesh, P(dcfg.worker_axes if dcfg else ()))
    return TrainState(
        params=pshard, opt_state=opt_sh,
        artemis=ArtemisDistState(h=h_sh, hbar=hbar_sh, e=e_sh, acc=acc_sh,
                                 prev_active=waxes_sh, step=rep),
        step=rep)


def make_train_step(model, optimizer, dcfg: Optional[DistConfig], mesh: Mesh,
                    grad_specs: Optional[PyTree] = None):
    """Build (init_state_fn, step_fn).

    dcfg=None   -> plain data-parallel baseline (jit only; XLA aggregates).
    dcfg given  -> Artemis over dcfg.worker_axes via partial-manual shard_map
                   (bucketed flat-ring wire by default; dcfg.wire="leaf" for
                   the legacy per-leaf rings).
    grad_specs  -> PartitionSpec tree (auto axes only) pinning the compressed
                   payload sharding inside the leaf-wire aggregation
                   (strongly recommended at scale; see artemis_aggregate).
    """
    sizes = _mesh_axis_sizes(mesh)
    n_workers = 1
    if dcfg:
        for a in dcfg.worker_axes:
            n_workers *= sizes[a]

    def _param_shard(leaf):
        # keep a caller-placed NamedSharding on this mesh; everything else
        # (fresh single-device init, abstract leaves) replicates
        sh = getattr(leaf, "sharding", None)
        if isinstance(sh, NamedSharding) and sh.mesh == mesh:
            return sh
        return NamedSharding(mesh, P())

    def init_state(params) -> TrainState:
        opt_state = optimizer.init(params)
        art = init_dist_state(dcfg, params, n_workers)
        state = TrainState(params, opt_state, art, jnp.zeros((), jnp.int32))
        # place the fresh state exactly where the step's outputs will live:
        # a SingleDeviceSharding state makes the SECOND step recompile the
        # whole program for the post-step NamedShardings
        pshard = jax.tree.map(_param_shard, params)
        return jax.device_put(state, state_shardings(mesh, state, pshard,
                                                     dcfg))

    k_local = dcfg.local_steps if dcfg else 1

    telem = dcfg is not None and dcfg.telemetry

    def sgd_core(params, opt_state, art, stepno, batch, wid):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        obs = None
        if dcfg is not None and dcfg.worker_axes and dcfg.bucketed:
            layout = dcfg.layout(grads)
            gb = bucketing.bucketize(layout, grads)
            if k_local > 1:
                # fold in the locally-accumulated gradients since last sync
                gb = (art.acc[0] + gb) / k_local
                art = art._replace(acc=jnp.zeros_like(art.acc))
            out = artemis_aggregate_bucketed(dcfg, art, gb, layout,
                                             n_workers, wid)
            (agg_b, art, obs) = out if telem else out + (None,)
            agg = bucketing.unbucketize(layout, agg_b, like=grads)
        else:
            if k_local > 1:
                grads = jax.tree.map(lambda a, g: (a[0] + g) / k_local,
                                     art.acc, grads)
                art = art._replace(acc=jax.tree.map(
                    lambda a: jnp.zeros_like(a), art.acc))
            if dcfg is not None and dcfg.worker_axes:
                out = artemis_aggregate(dcfg, art, grads, n_workers, wid,
                                        grad_specs)
                (agg, art, obs) = out if telem else out + (None,)
            else:
                agg = grads
                art = art._replace(step=art.step + 1)
        updates, opt_state = optimizer.update(agg, opt_state, stepno)
        params = jax.tree.map(lambda pp, u: (pp - u.astype(pp.dtype)).astype(pp.dtype),
                              params, updates)
        return params, opt_state, art, loss, metrics, obs

    if dcfg is None or not dcfg.worker_axes:
        def step_fn(state: TrainState, batch):
            params, opt_state, art, loss, metrics, _ = sgd_core(
                state.params, state.opt_state, state.artemis, state.step,
                batch, jnp.zeros((), jnp.int32))
            return (TrainState(params, opt_state, art, state.step + 1),
                    (loss, metrics))
        return init_state, step_fn

    waxes = dcfg.worker_axes
    strides = {}
    acc = 1
    for a in reversed(waxes):
        strides[a] = acc
        acc *= sizes[a]

    def step_fn(state: TrainState, batch):
        sspec = state_specs(dcfg, state)
        bspec = jax.tree.map(lambda _: P(waxes), batch)
        mspec = {"nll": P(), "aux": P()}
        if telem:
            # telemetry rides the metrics pytree; per-worker scalars are
            # psum'd to fleet totals, so the out-spec is replicated too
            mspec = {**mspec, "obs": {k: P() for k in obs_tel.MESH_METRICS}}

        def inner(st: TrainState, bt):
            wid = jnp.zeros((), jnp.int32)
            for a in waxes:
                wid = wid + jax.lax.axis_index(a) * strides[a]
            params, opt_state, art, loss, metrics, obs = sgd_core(
                st.params, st.opt_state, st.artemis, st.step, bt, wid)
            loss = jax.lax.pmean(loss, waxes)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, waxes), metrics)
            if telem:
                # totals over the worker ring (bytes moved, workers active,
                # payloads scrubbed, blowups injected this step)
                metrics = {**metrics,
                           "obs": jax.tree.map(
                               lambda x: jax.lax.psum(x, waxes), obs)}
            return (TrainState(params, opt_state, art, st.step + 1),
                    (loss, metrics))

        return shard_map_compat(inner, mesh, (sspec, bspec),
                                (sspec, (P(), mspec)), waxes)(state, batch)

    return init_state, step_fn
