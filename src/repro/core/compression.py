"""Unbiased compression operators (paper Assumption 5) + bit accounting.

Every operator C satisfies  E[C(x)] = x  and  E||C(x) - x||^2 <= omega * ||x||^2.

Since the codec refactor (DESIGN.md §9) the operators themselves live in
``core/codec.py`` as two-sided encode/decode pairs; this module keeps the
simulator-facing ``Compressor`` view: ``compress(key, x) -> x_hat`` is the
codec round-trip ``decode(encode(key, x))`` — bitwise identical to the
pre-codec one-shot operators (pinned by tests/test_codec.py).  The wire
format / bit cost is exposed separately via ``bits(shape)`` so the federated
simulator can meter communication using the Elias-code bound of Prop. S1
without actually entropy-coding.

The vector is treated as flat; callers may pass any-shaped arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.core import codec as wire

# re-exported: the bit-accounting constants/formulas now live with the codecs
FP_BITS = wire.FP_BITS
squant_omega = wire.squant_omega
squant_bits = wire.squant_bits


@dataclasses.dataclass(frozen=True)
class Compressor:
    """An unbiased compression operator with known variance factor omega."""

    name: str
    omega: float                       # Assumption-5 variance factor
    compress: Callable                 # (key, x) -> x_hat   (decoded value)
    bits: Callable                     # (n_elements,) -> float, cost per message
    unbiased: bool = True

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        return self.compress(key, x)


def from_codec(c: wire.Codec) -> Compressor:
    """The simulator view of a codec: compress == decode(encode(.))."""
    return Compressor(name=c.name, omega=c.omega, compress=c.__call__,
                      bits=c.bits, unbiased=c.unbiased)


def identity() -> Compressor:
    return from_codec(wire.make_codec("identity", 1))


def squant(d: int, s: int = 1) -> Compressor:
    """Global-norm s-quantization; ``d`` is the flattened message dimension."""
    return from_codec(wire.make_codec("squant", d, s=s))


def tile_squant(tile: int = 1024, s: int = 1) -> Compressor:
    """s-quantization with per-tile scales. omega is that of a ``tile``-dim
    message (each tile is an independent s-quantization)."""
    return from_codec(wire.make_codec("tile_squant", tile, s=s, tile=tile))


def sparsify(q: float) -> Compressor:
    """Keep each coordinate w.p. q, rescale by 1/q. omega = 1/q - 1 (Lemma S15)."""
    return from_codec(wire.make_codec("sparsify", 1, q=q))


def topk(frac: float) -> Compressor:
    """Exact top-k by |x| (jax.lax.top_k — ties no longer over-send)."""
    return from_codec(wire.make_codec("topk", 1, frac=frac))


def make_compressor(name: str, d: int, **kwargs) -> Compressor:
    return from_codec(wire.make_codec(name, d, **kwargs))
