"""Unbiased compression operators (paper Assumption 5) + bit accounting.

Every operator C satisfies  E[C(x)] = x  and  E||C(x) - x||^2 <= omega * ||x||^2.

Operators are *functional*: ``compress(key, x) -> x_hat`` where ``x_hat`` is the
dequantized (decoded) value.  The wire format / bit cost is exposed separately
via ``bits(shape)`` so the federated simulator can meter communication using
the Elias-code bound of Prop. S1 without actually entropy-coding.

The vector is treated as flat; callers may pass any-shaped arrays.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

FP_BITS = 32  # uncompressed scalar width used by the paper's bit accounting


@dataclasses.dataclass(frozen=True)
class Compressor:
    """An unbiased compression operator with known variance factor omega."""

    name: str
    omega: float                       # Assumption-5 variance factor
    compress: Callable                 # (key, x) -> x_hat   (decoded value)
    bits: Callable                     # (n_elements,) -> float, cost per message
    unbiased: bool = True

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        return self.compress(key, x)


# ---------------------------------------------------------------------------
# Identity (no compression) — omega = 0
# ---------------------------------------------------------------------------

def identity() -> Compressor:
    return Compressor(
        name="identity",
        omega=0.0,
        compress=lambda key, x: x,
        bits=lambda n: FP_BITS * n,
    )


# ---------------------------------------------------------------------------
# s-quantization (paper Definition 1 / QSGD, Alistarh et al. 2017)
# ---------------------------------------------------------------------------

def _squant(key: jax.Array, x: jax.Array, s: int) -> jax.Array:
    """C_s(x) = sign(x) * ||x||_2 * psi / s, with stochastic level rounding."""
    flat = x.reshape(-1)
    norm = jnp.linalg.norm(flat)
    # r in [0, s]: |x_j| / ||x|| * s
    r = jnp.where(norm > 0, jnp.abs(flat) / norm * s, jnp.zeros_like(flat))
    low = jnp.floor(r)
    prob_up = r - low
    u = jax.random.uniform(key, flat.shape)
    psi = low + (u < prob_up).astype(flat.dtype)
    out = jnp.sign(flat) * norm * psi / s
    return out.reshape(x.shape).astype(x.dtype)


def squant_omega(d: int, s: int) -> float:
    """omega_C = min(d/s^2, sqrt(d)/s)  (Alistarh et al., App. A.1)."""
    return min(d / s**2, math.sqrt(d) / s)


def squant_bits(n: int, s: int) -> float:
    """Elias-coded message size upper bound (Prop. S1)."""
    t = s * (s + math.sqrt(n))
    return (3.0 + 1.5 * math.log(2.0 * (s**2 + n) / t)) * t + FP_BITS


def squant(d: int, s: int = 1) -> Compressor:
    """Global-norm s-quantization; ``d`` is the flattened message dimension."""
    return Compressor(
        name=f"squant(s={s})",
        omega=squant_omega(d, s),
        compress=partial(_squant, s=s),
        bits=lambda n, s=s: squant_bits(n, s),
    )


# ---------------------------------------------------------------------------
# Per-tile s-quantization (TPU-native adaptation; see DESIGN.md §3)
# ---------------------------------------------------------------------------

def _tile_squant(key: jax.Array, x: jax.Array, s: int, tile: int) -> jax.Array:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % tile
    padded = jnp.pad(flat, (0, pad))
    tiles = padded.reshape(-1, tile)
    norms = jnp.linalg.norm(tiles, axis=1, keepdims=True)
    r = jnp.where(norms > 0, jnp.abs(tiles) / norms * s, jnp.zeros_like(tiles))
    low = jnp.floor(r)
    u = jax.random.uniform(key, tiles.shape)
    psi = low + (u < (r - low)).astype(tiles.dtype)
    out = jnp.sign(tiles) * norms * psi / s
    return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def tile_squant(tile: int = 1024, s: int = 1) -> Compressor:
    """s-quantization with per-tile scales. omega is that of a ``tile``-dim
    message (each tile is an independent s-quantization)."""
    return Compressor(
        name=f"tile_squant(s={s},t={tile})",
        omega=squant_omega(tile, s),
        compress=partial(_tile_squant, s=s, tile=tile),
        # ceil(n/tile) independent messages of dimension <= tile
        bits=lambda n, s=s, tile=tile: math.ceil(n / tile) * squant_bits(min(n, tile), s),
    )


# ---------------------------------------------------------------------------
# Stochastic sparsification (Wen et al. 2017; used in Theorem 3)
# ---------------------------------------------------------------------------

def _sparsify(key: jax.Array, x: jax.Array, q: float) -> jax.Array:
    mask = jax.random.bernoulli(key, q, x.shape)
    return jnp.where(mask, x / q, 0.0).astype(x.dtype)


def sparsify(q: float) -> Compressor:
    """Keep each coordinate w.p. q, rescale by 1/q. omega = 1/q - 1 (Lemma S15)."""
    return Compressor(
        name=f"sparsify(q={q})",
        omega=1.0 / q - 1.0,
        compress=partial(_sparsify, q=q),
        # indices (log2 n each) + values for ~qn survivors
        bits=lambda n, q=q: q * n * (FP_BITS + max(1.0, math.log2(max(n, 2)))),
    )


# ---------------------------------------------------------------------------
# Top-k (biased — contrast baseline; violates Assumption 5 unbiasedness)
# ---------------------------------------------------------------------------

def _topk(key: jax.Array, x: jax.Array, frac: float) -> jax.Array:
    del key
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jnp.sort(jnp.abs(flat))[-k]
    return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(x.shape)


def topk(frac: float) -> Compressor:
    return Compressor(
        name=f"topk({frac})",
        omega=1.0 - frac,   # contraction factor view; biased!
        compress=partial(_topk, frac=frac),
        bits=lambda n, frac=frac: frac * n * (FP_BITS + max(1.0, math.log2(max(n, 2)))),
        unbiased=False,
    )


_REGISTRY = {
    "identity": lambda d, **kw: identity(),
    "none": lambda d, **kw: identity(),
    "squant": lambda d, s=1, **kw: squant(d, s),
    "tile_squant": lambda d, s=1, tile=1024, **kw: tile_squant(tile, s),
    "sparsify": lambda d, q=0.25, **kw: sparsify(q),
    "topk": lambda d, frac=0.1, **kw: topk(frac),
}


def make_compressor(name: str, d: int, **kwargs) -> Compressor:
    if name not in _REGISTRY:
        raise ValueError(f"unknown compressor {name!r}; choose from {sorted(_REGISTRY)}")
    return _REGISTRY[name](d, **kwargs)
