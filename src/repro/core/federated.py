"""Federated/distributed simulator reproducing the paper's experiments (§5).

N workers with heterogeneous local datasets, a central server, partial
participation, bidirectional compression, and full uplink/downlink/catch-up
bit metering (Remark 3: a returning worker downloads the missed compressed
updates, or the whole model if it has been away > floor(M1/M2) rounds).

The whole optimization runs under one ``lax.scan`` so hundreds of iterations
for all 5+ algorithm variants finish in seconds on CPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import artemis as art
from repro.core import compression as comp


# ---------------------------------------------------------------------------
# Problems: least-squares regression & logistic regression (paper §C.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Problem:
    """N-worker problem with stacked data X: [N, n, d], Y: [N, n]."""
    X: jax.Array
    Y: jax.Array
    kind: str                   # 'lsr' | 'logistic'
    reg: float = 0.0            # l2 regularization (strong convexity floor)

    @property
    def n_workers(self) -> int:
        return self.X.shape[0]

    @property
    def dim(self) -> int:
        return self.X.shape[-1]

    def local_loss(self, w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
        pred = x @ w
        if self.kind == "lsr":
            per = 0.5 * (pred - y) ** 2
        elif self.kind == "logistic":
            per = jnp.logaddexp(0.0, -y * pred)
        else:
            raise ValueError(self.kind)
        return jnp.mean(per) + 0.5 * self.reg * jnp.sum(w**2)

    def global_loss(self, w: jax.Array) -> jax.Array:
        losses = jax.vmap(lambda x, y: self.local_loss(w, x, y))(self.X, self.Y)
        return jnp.mean(losses)

    def worker_grad(self, w: jax.Array, idx: jax.Array) -> jax.Array:
        """Stacked minibatch gradients [N, d]; idx: [N, b] sample indices."""
        def one(x, y, ix):
            xb, yb = x[ix], y[ix]
            return jax.grad(self.local_loss)(w, xb, yb)
        return jax.vmap(one)(self.X, self.Y, idx)

    def full_grad(self, w: jax.Array) -> jax.Array:
        def one(x, y):
            return jax.grad(self.local_loss)(w, x, y)
        return jax.vmap(one)(self.X, self.Y)

    def smoothness(self) -> float:
        """L estimate: max_i largest eigenvalue of (1/4 for logistic) X_i^T X_i / n."""
        def one(x):
            cov = x.T @ x / x.shape[0]
            return jnp.linalg.eigvalsh(cov)[-1]
        lam = jax.vmap(one)(self.X)
        scale = 1.0 if self.kind == "lsr" else 0.25
        return float(jnp.max(lam)) * scale + self.reg

    def solve_opt(self, iters: int = 3000) -> jax.Array:
        """w* by full-batch GD (closed-form for LSR)."""
        if self.kind == "lsr" and self.reg == 0.0:
            X = self.X.reshape(-1, self.dim)
            Y = self.Y.reshape(-1)
            return jnp.linalg.lstsq(X, Y)[0]
        L = self.smoothness()
        w = jnp.zeros((self.dim,))
        def body(w, _):
            g = jnp.mean(self.full_grad(w), axis=0)
            return w - (1.0 / L) * g, None
        w, _ = jax.lax.scan(body, w, None, length=iters)
        return w


# ---------------------------------------------------------------------------
# Synthetic datasets (paper §C.1)
# ---------------------------------------------------------------------------

def make_lsr_problem(key, n_workers=20, n_per=200, d=20, noise=0.4,
                     iid=True) -> Tuple[Problem, jax.Array]:
    """LSR: y = <w*, x> + e, e ~ N(0, noise^2). noise=0 => sigma_* = 0."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w_true = jax.random.normal(k1, (d,))
    if iid:
        X = jax.random.normal(k2, (n_workers, n_per, d))
    else:
        # per-worker anisotropic covariances -> heterogeneous distributions
        scales = 0.5 + jax.random.uniform(k4, (n_workers, 1, d)) * 2.0
        X = jax.random.normal(k2, (n_workers, n_per, d)) * scales
    E = noise * jax.random.normal(k3, (n_workers, n_per))
    Y = jnp.einsum("nbd,d->nb", X, w_true) + E
    return Problem(X=X, Y=Y, kind="lsr"), w_true


def make_logistic_problem(key, n_workers=20, n_per=200, d=2,
                          ) -> Problem:
    """Non-i.i.d. logistic: half the workers use model w1=(10,10,..),
    the other half w2=(10,-10,..), with distinct input covariances (§C.1)."""
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jnp.full((d,), 10.0).at[1:].set(10.0)
    w2 = jnp.full((d,), 10.0).at[1:].set(-10.0)
    # the two worker populations deliberately share ONE uniform draw so their
    # covariances are exact mirrors (cov1 + cov2 == 3); a fresh key here
    # would decouple them and shift the golden logistic problems
    cov1 = 1.0 + 0.5 * jax.random.uniform(k3, (d,))
    cov2 = 2.0 - 0.5 * jax.random.uniform(k3, (d,))  # repro-lint: allow=prng-key-reuse
    Xs, Ys = [], []
    keys = jax.random.split(k1, n_workers)
    for i in range(n_workers):
        cov = cov1 if i % 2 == 0 else cov2
        wm = w1 if i % 2 == 0 else w2
        x = jax.random.normal(keys[i], (n_per, d)) * cov
        pz = jax.nn.sigmoid(x @ wm)
        y = 2.0 * jax.random.bernoulli(jax.random.fold_in(k2, i), pz).astype(jnp.float32) - 1.0
        Xs.append(x)
        Ys.append(y)
    return Problem(X=jnp.stack(Xs), Y=jnp.stack(Ys), kind="logistic", reg=1e-3)


def make_clustered_problem(key, n_workers=20, n_per=400, d=40, noise=0.2) -> Problem:
    """Stand-in for the TSNE-clustered real datasets: each worker's inputs come
    from a distinct Gaussian cluster (non-i.i.d., unbalanced scales)."""
    kc, kx, kw, ke = jax.random.split(key, 4)
    centers = 3.0 * jax.random.normal(kc, (n_workers, d))
    X = centers[:, None, :] + jax.random.normal(kx, (n_workers, n_per, d))
    w_true = jax.random.normal(kw, (d,)) / jnp.sqrt(d)
    Y = jnp.einsum("nbd,d->nb", X, w_true) + noise * jax.random.normal(ke, (n_workers, n_per))
    return Problem(X=X, Y=Y, kind="lsr", reg=1e-3)


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunResult:
    losses: np.ndarray          # [iters] F(w_k)
    bits: np.ndarray            # [iters] cumulative communicated bits
    w_final: np.ndarray
    w_avg: np.ndarray           # Polyak-Ruppert average (all iterates)
    w_tail_avg: np.ndarray      # average over the last half (variance readout)
    dist_to_opt: Optional[np.ndarray] = None


def run(problem: Problem, cfg: art.ArtemisConfig, gamma: float, iters: int,
        key: jax.Array, batch: int = 1, w0: Optional[jax.Array] = None,
        full_batch: bool = False, w_star: Optional[jax.Array] = None,
        gamma_decay: bool = False, eval_every: int = 1,
        backend: Optional[str] = None) -> RunResult:
    """Run Artemis (any variant) on ``problem`` for ``iters`` rounds.

    Thin wrapper over the batched sweep engine (``core.sweep.run_sweep``)
    with a single-cell grid: repeated calls that differ only in ``gamma`` or
    ``key`` hit the compiled-program cache and re-trace zero times.  The
    original one-trace-per-call loop is kept as ``run_percell`` (legacy
    reference).

    Bit metering (unified rule, DESIGN.md §4): per round, every ACTIVE worker
    pays its uplink message plus the downlink catch-up — one compressed
    update per round missed since its last participation (>= 1: a worker
    active every round pays exactly this round's broadcast), capped at one
    full model once it has been away longer than floor(M1/M2) rounds
    (Remark 3).  Inactive workers communicate nothing.
    """
    from repro.core import sweep as _sweep   # lazy: sweep imports this module
    res = _sweep.run_sweep(
        problem, [cfg], [gamma], jnp.asarray(key)[None], iters, batch=batch,
        eval_every=eval_every, full_batch=full_batch, w0=w0, w_star=w_star,
        gamma_decay=gamma_decay, backend=backend)
    return RunResult(
        losses=res.losses[0, 0, 0],
        bits=res.bits[0, 0, 0],
        w_final=res.w_final[0, 0, 0],
        w_avg=res.w_avg[0, 0, 0],
        w_tail_avg=res.w_tail_avg[0, 0, 0],
        dist_to_opt=res.dists[0, 0, 0] if w_star is not None else None,
    )


def run_percell(problem: Problem, cfg: art.ArtemisConfig, gamma: float,
                iters: int, key: jax.Array, batch: int = 1,
                w0: Optional[jax.Array] = None, full_batch: bool = False,
                w_star: Optional[jax.Array] = None,
                gamma_decay: bool = False) -> RunResult:
    """Legacy single-cell loop: traces a fresh ``lax.scan`` per call and
    evaluates the full-batch loss every iteration.  Kept as the reference
    implementation the sweep engine is benchmarked and cross-checked against
    (benchmarks/dist_bench.py, tests/test_sweep.py)."""
    n, d = problem.n_workers, problem.dim
    n_per = problem.X.shape[1]
    c_up, c_dwn = cfg.compressors()
    m1 = comp.FP_BITS * d                        # full-model message
    m2 = max(c_dwn.bits(d), 1.0)                 # compressed-update message
    catchup_window = max(int(m1 // m2), 1)

    w0 = jnp.zeros((d,)) if w0 is None else w0
    state0 = art.init_state(cfg)
    last_part0 = -jnp.ones((n,), jnp.int32)      # k_i, last participation

    def step(carry, k):
        w, st, wsum, wtail, last_part = carry
        kk = jax.random.fold_in(key, k)
        k_idx, k_act, k_art = jax.random.split(kk, 3)
        if full_batch:
            grads = problem.full_grad(w)
        else:
            idx = jax.random.randint(k_idx, (n, batch), 0, n_per)
            grads = problem.worker_grad(w, idx)
        active = (jax.random.uniform(k_act, (n,)) < cfg.p).astype(jnp.float32)
        omega, st, stats = art.artemis_round(cfg, st, grads, k_art, active)
        g = gamma / jnp.sqrt(k + 1.0) if gamma_decay else gamma
        w = w - g * omega
        # --- catch-up bit metering (Remark 3) ------------------------------
        missed = k - last_part              # rounds since last download (>= 1)
        catch_bits = jnp.where(missed > catchup_window,
                               float(m1), missed.astype(jnp.float32) * m2)
        catch_bits = jnp.sum(active * catch_bits)
        last_part = jnp.where(active > 0, k, last_part).astype(jnp.int32)
        bits = stats["uplink_bits"] + catch_bits    # dwnlink counted in catch-up
        loss = problem.global_loss(w)
        wtail = wtail + jnp.where(k >= iters // 2, 1.0, 0.0) * w
        return (w, st, wsum + w, wtail, last_part), (loss, bits,
                                                     jnp.linalg.norm(w - (w_star if w_star is not None else 0.0)))

    (w, _, wsum, wtail, _), (losses, bits, dists) = jax.lax.scan(
        step, (w0, state0, jnp.zeros_like(w0), jnp.zeros_like(w0), last_part0),
        jnp.arange(iters))
    return RunResult(
        losses=np.asarray(losses),
        bits=np.asarray(jnp.cumsum(bits)),
        w_final=np.asarray(w),
        w_avg=np.asarray(wsum / iters),
        w_tail_avg=np.asarray(wtail / max(iters - iters // 2, 1)),
        dist_to_opt=np.asarray(dists) if w_star is not None else None,
    )


def gamma_max(problem: Problem, cfg: art.ArtemisConfig) -> float:
    """Step-size upper bound from Table 3 / Theorems S5-S6."""
    c_up, c_dwn = cfg.compressors()
    L = problem.smoothness()
    N, p = cfg.n_workers, cfg.p
    wu, wd = c_up.omega, c_dwn.omega
    if cfg.resolved_alpha() == 0.0:   # Thm S5
        return p * N / (L * (wd + 1) * (p * N + 2 * (wu + 1)))
    # Thm S6 (minimum of the three constraints)
    g1 = 1.0 / ((wd + 1) * (1 + 2.0 / (N * p)) * L)
    g2 = 3.0 / ((wd + 1) * (3 + 8 * (wu + 1) * (N + 2) / (N * p)) * L)
    g3 = N / ((wd + 1) * (N + 4 * (wu + 1) / p - 2) * L)
    return min(g1, g2, g3)
