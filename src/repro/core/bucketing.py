"""Flat fixed-size bucketing of gradient pytrees for the compressed wire.

The mesh backend's wire layer (``core/dist.py``) does not ship one message
per pytree leaf — it flattens the whole gradient into ``<= max_buckets``
equal byte-size f32 buckets and ships one contiguous ``int8 levels +
f32 row-scales`` payload per bucket (DESIGN.md §7).  This module owns the
*index map* side of that contract:

  * ``make_layout``   — static bucket geometry for a pytree structure:
                        ``n_buckets`` buckets of ``rows x row`` f32 each,
                        computed once per (tree, bucket_bytes) at trace time;
  * ``bucketize``     — leaves -> [B, R, C] f32 (tail zero-padded);
  * ``unbucketize``   — exact inverse via the stored offsets (padding
                        dropped, leaf shapes/dtypes restored).

Buckets are always *equal* size: the tail bucket is zero-padded rather than
shortened, so every ring hop moves the same payload and the pipelined
schedule has no ragged final step.  The row length ``row`` (wire column
count C) is the per-row-scale granularity of the bucketed quantizer — the
per-tile omega rule of DESIGN.md §3 applies with tile size ``row``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

DEFAULT_BUCKET_BYTES = 1 << 16      # 64 KiB of f32 payload per bucket
DEFAULT_MAX_BUCKETS = 16            # the "<= K" cap of ISSUE 6 / DESIGN §7
DEFAULT_ROW = 256                   # wire row length C (per-row scale tile)


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static index map between a pytree and its [B, R, C] bucket stack."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]   # leaf shapes, flatten order
    sizes: Tuple[int, ...]                # leaf element counts
    offsets: Tuple[int, ...]              # leaf start offsets in the flat vec
    total: int                            # sum(sizes)
    n_buckets: int                        # B
    rows: int                             # R
    row: int                              # C

    @property
    def bucket_elems(self) -> int:
        return self.rows * self.row

    @property
    def padded_total(self) -> int:
        return self.n_buckets * self.bucket_elems

    @property
    def pad(self) -> int:
        return self.padded_total - self.total

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.n_buckets, self.rows, self.row)

    @property
    def level_bytes(self) -> int:
        """int8 wire bytes of one worker's levels payload."""
        return self.padded_total

    @property
    def scale_bytes(self) -> int:
        """f32 wire bytes of one worker's per-row scales payload."""
        return 4 * self.n_buckets * self.rows


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def make_layout(tree: PyTree, *, bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                max_buckets: int = DEFAULT_MAX_BUCKETS,
                row: int = DEFAULT_ROW) -> BucketLayout:
    """Bucket geometry for ``tree`` (arrays, tracers, or ShapeDtypeStructs).

    The target bucket size is ``bucket_bytes`` of f32 payload, rounded up to
    a multiple of ``row``; if that would need more than ``max_buckets``
    buckets, buckets grow so exactly ``max_buckets`` cover the tree.  The
    same inputs always produce the same layout, so calling this at trace
    time inside a jitted step is free and deterministic.
    """
    if bucket_bytes <= 0 or max_buckets <= 0 or row <= 0:
        raise ValueError((bucket_bytes, max_buckets, row))
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(int(d) for d in l.shape) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    total = off
    if total == 0:
        raise ValueError("cannot bucketize an empty pytree")
    elems = _round_up(max(bucket_bytes // 4, row), row)
    elems = min(elems, _round_up(total, row))    # bucket_bytes=inf -> B=1
    n_buckets = -(-total // elems)
    if n_buckets > max_buckets:
        elems = _round_up(-(-total // max_buckets), row)
        n_buckets = -(-total // elems)
    return BucketLayout(treedef=treedef, shapes=shapes, sizes=sizes,
                        offsets=tuple(offsets), total=total,
                        n_buckets=n_buckets, rows=elems // row, row=row)


def bucketize(layout: BucketLayout, tree: PyTree) -> jax.Array:
    """Pytree -> [B, R, C] f32 bucket stack (tail zero-padded)."""
    leaves = layout.treedef.flatten_up_to(tree)
    flat = jnp.concatenate(
        [jnp.ravel(l).astype(jnp.float32) for l in leaves])
    if layout.pad:
        flat = jnp.concatenate([flat, jnp.zeros((layout.pad,), jnp.float32)])
    return flat.reshape(layout.shape)


def unbucketize(layout: BucketLayout, buckets: jax.Array,
                like: Optional[PyTree] = None) -> PyTree:
    """Exact inverse of ``bucketize`` (padding dropped).

    ``like``: optional pytree whose leaf dtypes the output is cast to.
    """
    flat = buckets.reshape(-1)[:layout.total]
    leaves = [flat[o:o + s].reshape(shape)
              for o, s, shape in zip(layout.offsets, layout.sizes,
                                     layout.shapes)]
    out = jax.tree.unflatten(layout.treedef, leaves)
    if like is not None:
        out = jax.tree.map(lambda o, l: o.astype(l.dtype), out, like)
    return out


def bucket_keys(key: jax.Array, n_buckets: int) -> jax.Array:
    """Per-bucket PRNG keys: fold the bucket index into ``key``.

    Keeping one key per bucket (rather than one per leaf) makes the bucketed
    quantization stream reproducible for a fixed layout — the dense-path
    equivalence tests replay it outside the mesh program.
    """
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(n_buckets))


def encode_buckets(codec, key: jax.Array, buckets: jax.Array):
    """Encode a [B, R, C] bucket stack with any ``core/codec.py`` codec, one
    PRNG key per bucket: returns a stacked ``WirePayload`` whose leaves all
    carry a leading B axis (the unit the ring permutes)."""
    keys = bucket_keys(key, buckets.shape[0])
    return jax.vmap(codec.encode)(keys, buckets)


def decode_buckets(codec, payload) -> jax.Array:
    """Inverse of ``encode_buckets``: stacked payload -> [B, R, C] f32."""
    return jax.vmap(codec.decode)(payload)
