#!/usr/bin/env bash
# Minimal CI gate. Stages:
#   0. static analysis  (repro.analysis --ci: ast lint incl. the
#      tracked-bytecode hygiene rule, compile-count trace audit, HLO
#      wire/donation/host-transfer checks; fails on any unsuppressed
#      finding) + codec conformance: the wire-codec registry suite and the
#      all-codec HLO wire-format guard (roofline wire_bytes vs measured
#      collective-permute bytes per dtype)
#   1. fast test tier   (tier-1: pytest default set, < 2 min budget)
#   2. slow test tier   (model-zoo smoke, XLA-compile bound)
#   3. benchmark smoke  (one grid cell per suite; catches API rot cheaply;
#      writes BENCH_dist.json [wire-layer fast numbers] next to
#      BENCH_sweep.json — committed versions come from a non-fast run)
#   4. fault matrix     (self-healing smoke: inject NaN blowups / huge
#      finite blowups / wire bit-flips, assert scrubbing + sentinel recover)
#   5. observability    (instrumented sweep smoke: schema-valid JSONL event
#      log, Perfetto trace artifact, markdown dashboard, and the
#      BENCH_history.jsonl append-only regression gate — repro.obs)
set -euo pipefail
cd "$(dirname "$0")"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== stage 0: static analysis + codec conformance ==="
python -m repro.analysis --ci
python -m pytest -x -q tests/test_codec.py
python tests/helpers/bucket_scenarios.py codec_wire_guard

echo "=== stage 1: fast tests ==="
python -m pytest -x -q

echo "=== stage 2: slow tests (model zoo) ==="
python -m pytest -x -q -m slow

echo "=== stage 3: benchmark smoke (--fast) ==="
python benchmarks/run.py --fast

echo "=== stage 4: fault-matrix smoke ==="
python benchmarks/fault_bench.py --matrix

echo "=== stage 5: observability smoke + bench gate ==="
# instrumented sweep: JSONL events + Perfetto trace + dashboard, then
# validate the log and gate the appended metrics against the ledger window
python -m repro.obs smoke -o /tmp/repro_obs_ci --ledger BENCH_history.jsonl
python -m repro.obs validate /tmp/repro_obs_ci/events.jsonl
python -m repro.obs bench-check BENCH_history.jsonl
echo "CI OK"
