"""Standalone wire-layer benchmark: bucketed pipelined ring vs per-leaf rings.

Times a full Artemis train step on a simulated W-worker CPU mesh (fake
devices; XLA device count is locked at first jax import, hence a standalone
script run in a subprocess by ``benchmarks/dist_bench.bucketed_ring_suite``)
for each wire, records the compiled HLO's collective bytes by dtype, and
emits one JSON report on stdout with the roofline wire-model numbers
alongside the measurements.

    python benchmarks/bucket_ring_bench.py [--workers 8] [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

parser = argparse.ArgumentParser()
parser.add_argument("--workers", type=int, default=8)
parser.add_argument("--fast", action="store_true")
ARGS = parser.parse_args()

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={ARGS.workers}")

import jax                                               # noqa: E402
import jax.numpy as jnp                                  # noqa: E402

from repro.core import dist                              # noqa: E402
from repro.launch import roofline                        # noqa: E402
from repro.models.toy import ToyMLP                      # noqa: E402
from repro.optim import sgd                              # noqa: E402


def bench_wire(wire: str, model, params, batch, mesh, *, steps: int):
    dcfg = dist.DistConfig(worker_axes=("pod",), variant="artemis", s=3,
                           wire=wire, bucket_bytes=4096, max_buckets=16,
                           bucket_row=64)
    init_state, step_fn = dist.make_train_step(model, sgd(0.05), dcfg, mesh)
    state = init_state(params)

    t0 = time.time()
    compiled = jax.jit(step_fn).lower(state, batch).compile()
    compile_s = time.time() - t0
    hlo = compiled.as_text()
    by_dtype = roofline.collective_dtype_bytes(hlo)

    jstep = jax.jit(step_fn)
    for _ in range(2):                                     # warmup
        state, out = jstep(state, batch)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(steps):
        state, (loss, _) = jstep(state, batch)
    loss = float(jax.block_until_ready(loss))
    step_us = (time.time() - t0) / steps * 1e6

    n = ARGS.workers
    if wire == "bucketed":
        lay = dcfg.layout(params)
        mdl = roofline.bucketed_wire_model(
            n_workers=n, n_buckets=lay.n_buckets, rows=lay.rows, row=lay.row)
        guard = roofline.wire_bytes_match(hlo, mdl)
        extra = {"layout": {"n_buckets": lay.n_buckets, "rows": lay.rows,
                            "row": lay.row, "pad": lay.pad},
                 "wire_guard": guard}
    else:
        shapes = [tuple(l.shape) for l in jax.tree.leaves(params)]
        mdl = roofline.leaf_wire_model(shapes, n_workers=n)
        extra = {"n_leaves": len(shapes)}
    return {
        "step_us": round(step_us, 1),
        "compile_s": round(compile_s, 3),
        "final_loss": loss,
        "hlo_collective_bytes": {f"{k}/{d}": v
                                 for (k, d), v in sorted(by_dtype.items())},
        "model": {k: (round(v, 12) if isinstance(v, float) else v)
                  for k, v in mdl.items()},
        **extra,
    }


def main():
    steps = 3 if ARGS.fast else 10
    model = ToyMLP(n_layers=6 if ARGS.fast else 12, d=64)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.batch(jax.random.PRNGKey(1), n=4 * ARGS.workers)
    mesh = dist.make_worker_mesh((ARGS.workers,), ("pod",))

    wires = {w: bench_wire(w, model, params, batch, mesh, steps=steps)
             for w in ("leaf", "bucketed")}
    report = {
        "workers": ARGS.workers,
        "fast": ARGS.fast,
        "steps_timed": steps,
        "model": {"n_layers": model.n_layers, "d": model.d,
                  "n_leaves": len(jax.tree.leaves(params)),
                  "n_params": int(sum(l.size for l in jax.tree.leaves(params)))},
        "wires": wires,
        "speedup_bucketed_vs_leaf": round(
            wires["leaf"]["step_us"] / wires["bucketed"]["step_us"], 2),
        "device": jax.devices()[0].device_kind,
        "jax": jax.__version__,
    }
    json.dump(report, sys.stdout, indent=2)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
