"""Fault-injection benchmarks: scrubbing overhead + recovery smoke matrix.

Three entry points:

  * ``fault_overhead_suite`` (via ``benchmarks/run.py``): measures the cost
    of the server defenses at two scales —
      - mesh backend (the number that matters): end-to-end bucketed-wire
        train step with finite/checksum scrubbing ON vs OFF, on a simulated
        multi-worker mesh (subprocess; fake CPU devices).  Budget:
        <5%/round — scrubbing is a few elementwise isfinite/where passes
        over payloads a real model's fwd/bwd dwarfs.
      - sweep engine (informational): the same toggle on the tiny-problem
        sweep grid, where rounds are a handful of flops and the relative
        overhead is intrinsically inflated.
    The report is merged into BENCH_dist.json under a ``"fault_bench"`` key
    (read-modify-write: the bucketed-ring suite owns the rest of that file
    and runs first).

  * ``python benchmarks/fault_bench.py --matrix``: the CI fault-matrix
    smoke — injects NaN blowups, huge finite blowups, and wire bit-flips
    and asserts the self-healing server actually recovers (finite
    converging losses, sentinel rollbacks engaged, zero-fault identity
    bitwise).  Exits non-zero on any failed recovery.

  * ``--step-child <wire> <scrub>``: internal subprocess body for the mesh
    measurement (device count must be fixed before jax initializes).
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

FAST = False      # set by benchmarks/run.py --fast: one cell, few iters

BENCH_DIST_JSON = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_dist.json")

OVERHEAD_BUDGET_PCT = 5.0


# ---------------------------------------------------------------------------
# mesh-backend step overhead (subprocess: fake devices precede jax init)
# ---------------------------------------------------------------------------

def run_step_child(wire: str, scrub: bool):
    import jax
    from repro.core import dist, faults
    from repro.models.toy import ToyMLP
    from repro.optim import sgd

    workers = 4 if FAST else 8
    mesh = dist.make_worker_mesh((workers,), ("pod",))
    model = ToyMLP(n_layers=4, d=256)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = dist.DistConfig(
        worker_axes=("pod",), variant="artemis", s=3, p_participation=0.7,
        wire=wire, bucket_row=64,
        faults=faults.FaultConfig(scrub=True) if scrub else None)
    init_state, step_fn = dist.make_train_step(model, sgd(0.05), dcfg, mesh)
    state = init_state(params)
    batch = model.batch(jax.random.PRNGKey(1), n=32)
    jstep = jax.jit(step_fn)
    state, out = jstep(state, batch)
    jax.block_until_ready(out)
    # best-of-reps: a single long loop folds transient machine load into
    # the mean; the min over short reps is the stable per-step cost
    reps, iters = (2, 5) if FAST else (8, 10)
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        for _ in range(iters):
            state, out = jstep(state, batch)
        jax.block_until_ready(out)
        best = min(best, (time.time() - t0) / iters)
    print(json.dumps({"step_us": best * 1e6}))


def _step_us(wire: str, scrub: bool) -> float:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d" % (
        4 if FAST else 8)
    src = os.path.join(_ROOT, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--step-child", wire,
           "1" if scrub else "0"] + (["--fast"] if FAST else [])
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"step child failed:\n{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])["step_us"]


# ---------------------------------------------------------------------------
# sweep-engine overhead (informational: toy rounds inflate the relative cost)
# ---------------------------------------------------------------------------

def _sweep_walls():
    import jax
    from repro.core import artemis as art
    from repro.core import faults
    from repro.core import federated as fed
    from repro.core import sweep as sw

    n, d = 20, 20
    iters = 20 if FAST else 200
    prob, _ = fed.make_lsr_problem(jax.random.PRNGKey(11), n_workers=n,
                                   n_per=200, d=d, noise=0.4)
    variants = ["artemis"] if FAST else ["qsgd", "artemis", "dore"]

    def grid(fc):
        return [dataclasses.replace(art.variant_config(v, d, n, p=0.7),
                                    faults=fc) for v in variants]

    def timed(fc):
        kw = dict(gammas=[0.02, 0.05], seeds=[0, 1], iters=iters, batch=4,
                  eval_every=10 if not FAST else 1)
        sw.run_sweep(prob, grid(fc), **kw)            # compile + warm cache
        best = float("inf")
        for _ in range(3):
            t0 = time.time()
            sw.run_sweep(prob, grid(fc), **kw)
            best = min(best, time.time() - t0)
        return best

    cells = len(variants) * 2 * 2
    return (cells, iters, timed(None), timed(faults.FaultConfig(scrub=True)),
            timed(faults.FaultConfig(bitflip_rate=0.01, scrub=True,
                                     sentinel=1e6)))


def fault_overhead_suite():
    """Scrubbing cost: mesh step (<5% budget) + sweep engine (informational)."""
    # paired back-to-back measurements, median of per-pair ratios: ambient
    # load on the simulated mesh drifts on a seconds scale, so a ratio taken
    # within one pair is far more stable than any absolute best-of
    pairs = []
    for _ in range(1 if FAST else 3):
        pairs.append((_step_us("bucketed", scrub=False),
                      _step_us("bucketed", scrub=True)))
    pairs.sort(key=lambda p: (p[1] - p[0]) / p[0])
    base_us, scrub_us = pairs[len(pairs) // 2]
    mesh_pct = (scrub_us - base_us) / base_us * 100.0

    cells, iters, sw_base, sw_scrub, sw_full = _sweep_walls()
    report = {
        "mesh_step_us": round(base_us, 1),
        "mesh_step_scrub_us": round(scrub_us, 1),
        "mesh_scrub_overhead_pct": round(mesh_pct, 2),
        "overhead_budget_pct": OVERHEAD_BUDGET_PCT,
        "scrub_within_budget": mesh_pct < OVERHEAD_BUDGET_PCT,
        "sweep_grid_cells": cells,
        "sweep_iters": iters,
        "sweep_baseline_wall_s": round(sw_base, 4),
        "sweep_scrub_wall_s": round(sw_scrub, 4),
        "sweep_scrub_overhead_pct": round((sw_scrub - sw_base) / sw_base * 100,
                                          1),
        "sweep_full_defense_wall_s": round(sw_full, 4),
    }
    if not FAST and os.path.exists(BENCH_DIST_JSON):
        # bucketed_ring_suite owns this file and rewrites it wholesale;
        # merge our key into whatever it last produced
        with open(BENCH_DIST_JSON) as f:
            full = json.load(f)
        full["fault_bench"] = report
        with open(BENCH_DIST_JSON, "w") as f:
            json.dump(full, f, indent=2)
            f.write("\n")

    return [
        ("fault/mesh_step", base_us, "bucketed wire, defenses off"),
        ("fault/mesh_step_scrub", scrub_us,
         f"overhead={mesh_pct:+.1f}% budget<{OVERHEAD_BUDGET_PCT:.0f}% "
         f"ok={mesh_pct < OVERHEAD_BUDGET_PCT}"),
        ("fault/sweep_scrub", sw_scrub * 1e6 / (cells * iters),
         f"toy-round overhead={(sw_scrub - sw_base) / sw_base * 100:+.1f}% "
         "(informational)"),
        ("fault/sweep_full_defense", sw_full * 1e6 / (cells * iters),
         f"scrub+flip+sentinel wall_s={sw_full:.3f}"),
    ]


ALL = [fault_overhead_suite]


# ---------------------------------------------------------------------------
# --matrix: CI recovery smoke
# ---------------------------------------------------------------------------

def run_matrix():
    import jax
    import numpy as np
    from repro.core import artemis as art
    from repro.core import faults
    from repro.core import federated as fed
    from repro.core import sweep as sw

    n, d = 8, 16
    prob, _ = fed.make_lsr_problem(jax.random.PRNGKey(3), n_workers=n,
                                   n_per=50, d=d, noise=0.3)

    def run(fc, backend=None):
        cfg = dataclasses.replace(art.variant_config("artemis", d, n, p=0.7),
                                  faults=fc)
        return sw.run_sweep(prob, [cfg], [0.02], [0], iters=40, batch=4,
                            backend=backend)

    # zero-fault identity: the harness itself must be invisible when off
    base, zero = run(None), run(faults.FaultConfig())
    assert np.array_equal(base.losses, zero.losses), "zero-fault identity"

    # NaN blowups + scrubbing: corrupt workers masked inactive, run converges
    res = run(faults.FaultConfig(blowup_rate=0.25, scrub=True))
    last, first = res.losses[0, 0, 0, -1], res.losses[0, 0, 0, 0]
    assert np.all(np.isfinite(res.losses)) and last < first, "scrub recovery"

    # huge finite blowups + sentinel: rollback engaged, gamma backed off
    res = run(faults.FaultConfig(blowup_rate=0.1, blowup_value=1e15,
                                 scrub=True, sentinel=1e3))
    assert np.all(np.isfinite(res.losses)), "sentinel kept losses finite"
    assert int(res.rollbacks[0, 0, 0]) >= 1, "sentinel never rolled back"
    assert float(res.gamma_scale[0, 0, 0]) < 1.0, "gamma never backed off"

    # wire bit-flips on the quantized (pallas) wire: scrub + sentinel recover
    res = run(faults.FaultConfig(bitflip_rate=0.05, scrub=True, sentinel=1e4),
              backend="pallas")
    assert np.all(np.isfinite(res.losses)), "bitflip recovery (pallas wire)"

    print("fault matrix: OK (identity, scrub, sentinel, bitflip)")


if __name__ == "__main__":
    if "--fast" in sys.argv:
        FAST = True
    if "--step-child" in sys.argv:
        i = sys.argv.index("--step-child")
        run_step_child(sys.argv[i + 1], sys.argv[i + 2] == "1")
    elif "--matrix" in sys.argv:
        run_matrix()
    else:
        print("name,us_per_call,derived")
        for row in fault_overhead_suite():
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
