"""Pallas kernel microbenchmarks (CPU interpret timings + HBM traffic model).

Wall-times on CPU interpret mode are NOT TPU predictions; the derived column
carries the *memory-traffic model* (bytes moved per element), which is what
the fused kernel improves and what the TPU memory roofline sees.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels import squant as sq
from repro.kernels import fused_memory as fm

KEY = jax.random.PRNGKey(0)

FAST = False      # set by benchmarks/run.py --fast: small shapes, 1 rep


def _bench(fn, *args, reps=5):
    if FAST:
        reps = 1
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def kernel_suite():
    m, n = (256, 256) if FAST else (1024, 1024)
    x = jax.random.normal(KEY, (m, n))
    u = jax.random.uniform(jax.random.PRNGKey(1), (m, n))
    h = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (m, n))
    block = (256, 256)
    rows = []

    us = _bench(lambda: sq.squant_encode(x, u, s=1, block=block, interpret=True))
    rows.append(("kernel/squant_encode", us, "bytes_per_elem=4r+1w+0.0002s"))

    us_ref = _bench(lambda: ref.squant_encode_ref(x, u, 1, *block))
    rows.append(("kernel/squant_encode_ref", us_ref, "oracle"))

    us = _bench(lambda: fm.fused_memory_update(x, h, u, 0.25, s=1, block=block,
                                               interpret=True))
    # unfused: delta=g-h (2r 1w), encode (2r 1w), decode+h update (3r 1w)
    # fused: g,h,u read once; q, h_new written once
    rows.append(("kernel/fused_memory", us,
                 "hbm_passes fused=3r2w vs unfused=7r3w (1.67x less traffic)"))

    def unfused(g, hh, uu):
        q, s_ = sq.squant_encode(g - hh, uu, s=1, block=block, interpret=True)
        dh = sq.squant_decode(q, s_, block=block, interpret=True)
        return q, s_, hh + 0.25 * dh
    us = _bench(lambda: unfused(x, h, u))
    rows.append(("kernel/unfused_memory", us, "reference pipeline"))

    q, s_ = sq.squant_encode(x, u, s=1, block=block, interpret=True)
    us = _bench(lambda: sq.dequant_apply(h, q, s_, 0.01, block=block,
                                         interpret=True))
    rows.append(("kernel/dequant_apply", us, "fused optimizer apply"))

    # server-side ring accumulation (fused dequant-accumulate of N payloads)
    from repro.kernels import ring_sum as rs
    nq = jax.random.randint(jax.random.PRNGKey(4), (4, m, n), -2, 3,
                            dtype=jnp.int8)
    ns = jax.random.uniform(jax.random.PRNGKey(5), (4, m, 1))
    us = _bench(lambda: rs.ring_sum(nq, ns, interpret=True))
    rows.append(("kernel/ring_sum", us,
                 "fused N-payload dequant-accumulate, 1 f32 write"))
    us = _bench(lambda: rs.ring_sum_ref(nq, ns))
    rows.append(("kernel/ring_sum_ref", us, "oracle"))

    # wire-format compression ratio
    c, shape = ops.encode(KEY, x, s=1)
    ratio = (x.size * 4) / c.wire_bytes
    rows.append(("kernel/wire_ratio", 0.0, f"fp32_bytes/wire_bytes={ratio:.2f}"))
    return rows


ALL = [kernel_suite]
