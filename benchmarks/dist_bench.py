"""Distributed-step benchmark: Artemis vs baseline on a host mesh.

Times one optimizer step of a reduced arch with/without compressed
aggregation, and reports the analytic inter-worker wire bytes — the quantity
the paper's technique reduces (and §Roofline's collective term measures on
the production mesh).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import dist
from repro.launch import mesh as M
from repro.models.model import build_model
from repro.optim import sgd


def _wire_bytes(params, variant, n_workers, s=1):
    """Analytic per-step inter-worker bytes per worker (uplink+downlink)."""
    total_f32 = sum(l.size * 4 for l in jax.tree.leaves(params))
    total_int8 = sum(l.size for l in jax.tree.leaves(params))
    scales = sum((l.size // l.shape[-1] if l.ndim else 1) * 4
                 for l in jax.tree.leaves(params))
    ring_f32 = 2 * (n_workers - 1) / n_workers * total_f32      # all-reduce
    ring_q = (n_workers - 1) * (total_int8 + scales) / n_workers
    if variant == "sgd":
        return ring_f32
    up = ring_q
    dwn = 0.0 if variant in ("biqsgd", "artemis") else ring_f32 / 2
    return up + dwn


def dist_step_suite():
    rows = []
    mesh = M.make_host_mesh()
    cfg = configs.get_config("starcoder2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 128), 0,
                                          cfg.vocab)}
    n_workers = jax.device_count()
    with jax.set_mesh(mesh):
        for variant in ["none", "sgd", "qsgd", "artemis"]:
            dcfg = None if variant == "none" else dist.DistConfig(
                worker_axes=("data",), variant=variant)
            init_state, step_fn = dist.make_train_step(model, sgd(0.01), dcfg,
                                                       mesh)
            state = init_state(params)
            jstep = jax.jit(step_fn)
            state, out = jstep(state, batch)
            jax.block_until_ready(out)
            t0 = time.time()
            for _ in range(3):
                state, out = jstep(state, batch)
            jax.block_until_ready(out)
            us = (time.time() - t0) / 3 * 1e6
            wire = _wire_bytes(params, variant if variant != "none" else "sgd",
                               max(n_workers, 2))
            rows.append((f"dist_step/{variant}", us,
                         f"wire_bytes_per_worker={wire:.3e}"))
    return rows


ALL = [dist_step_suite]
