"""Distributed benchmarks.

Default suites:
  * sweep engine — the batched one-trace grid vs the seed's per-cell Python
    loop on the paper's experiment grid (6 variants x 4 step-sizes x 3
    seeds, 200 rounds), plus the ``group_by_variant=True`` partitioned mode
    (V traces, 1x arithmetic — the §5 crossover data).  Written to
    BENCH_sweep.json.
  * bucketed ring — the bucketed pipelined compressed wire vs the legacy
    per-leaf sequential rings, timed end-to-end on a simulated multi-host
    mesh (subprocess with fake CPU devices; ``bucket_ring_bench.py``) with
    the compiled HLO's collective bytes checked against the roofline wire
    model.  Written to BENCH_dist.json.

The legacy host-mesh optimizer-step suite is kept behind a capability guard
(it needs the explicit-sharding jax API that this container's jax may lack).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import artemis as art
from repro.core import federated as fed
from repro.core import sweep as sw

FAST = False      # set by benchmarks/run.py --fast: one cell, few iters

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweep.json")
BENCH_DIST_JSON = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_dist.json")

VARIANTS = ["sgd", "qsgd", "diana", "biqsgd", "artemis", "dore"]
GAMMA_FRACS = [0.125, 0.25, 0.5, 1.0]
SEEDS = [0, 1, 2]
ITERS = 200
EVAL_EVERY = 10


def sweep_engine_suite():
    """One-trace multi-variant grid vs the seed's per-cell loop."""
    n, d = 20, 20
    variants = VARIANTS[:1] if FAST else VARIANTS
    fracs = GAMMA_FRACS[:1] if FAST else GAMMA_FRACS
    seeds = SEEDS[:1] if FAST else SEEDS
    iters = 20 if FAST else ITERS

    prob, _ = fed.make_lsr_problem(jax.random.PRNGKey(11), n_workers=n,
                                   n_per=200, d=d, noise=0.4)
    cfgs = [art.variant_config(v, d, n) for v in variants]
    g_ref = fed.gamma_max(prob, art.variant_config("artemis", d, n))
    gammas = [f * g_ref for f in fracs]
    cells = len(cfgs) * len(gammas) * len(seeds)

    # --- the seed's per-cell Python loop: one trace + per-round loss each ---
    t0 = time.time()
    for cfg in cfgs:
        for g in gammas:
            for s in seeds:
                fed.run_percell(prob, cfg, gamma=g, iters=iters,
                                key=jax.random.PRNGKey(s), batch=1)
    percell_s = time.time() - t0

    # --- sweep engine: cold (includes the single compile), then warm -------
    t0 = time.time()
    res_cold = sw.run_sweep(prob, cfgs, gammas, seeds, iters, batch=1,
                            eval_every=EVAL_EVERY if not FAST else 1)
    cold_s = time.time() - t0
    t0 = time.time()
    res_warm = sw.run_sweep(prob, cfgs, gammas, seeds, iters, batch=1,
                            eval_every=EVAL_EVERY if not FAST else 1)
    warm_s = time.time() - t0

    # --- grouped mode: V single-variant traces, 1x round arithmetic -------
    t0 = time.time()
    res_gcold = sw.run_sweep(prob, cfgs, gammas, seeds, iters, batch=1,
                             eval_every=EVAL_EVERY if not FAST else 1,
                             group_by_variant=True)
    gcold_s = time.time() - t0
    t0 = time.time()
    res_gwarm = sw.run_sweep(prob, cfgs, gammas, seeds, iters, batch=1,
                             eval_every=EVAL_EVERY if not FAST else 1,
                             group_by_variant=True)
    gwarm_s = time.time() - t0

    report = {
        "grid": {"variants": variants, "n_gammas": len(gammas),
                 "n_seeds": len(seeds), "cells": cells, "iters": iters,
                 "eval_every": EVAL_EVERY if not FAST else 1,
                 "n_workers": n, "dim": d},
        "percell_wall_s": round(percell_s, 3),
        "sweep_cold_wall_s": round(cold_s, 3),
        "sweep_warm_wall_s": round(warm_s, 3),
        "speedup_cold": round(percell_s / cold_s, 2),
        "speedup_warm": round(percell_s / warm_s, 2),
        "cells_per_sec_warm": round(cells / warm_s, 2),
        "traces_cold": res_cold.traces,
        "traces_warm": res_warm.traces,
        "grouped_cold_wall_s": round(gcold_s, 3),
        "grouped_warm_wall_s": round(gwarm_s, 3),
        "grouped_traces_cold": res_gcold.traces,
        "grouped_traces_warm": res_gwarm.traces,
        "device": jax.devices()[0].device_kind,
        "jax": jax.__version__,
    }
    if not FAST:
        with open(BENCH_JSON, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")

    rows = [
        ("sweep/percell_loop", percell_s * 1e6 / (cells * iters),
         f"wall_s={percell_s:.2f} traces~{cells}"),
        ("sweep/engine_cold", cold_s * 1e6 / (cells * iters),
         f"wall_s={cold_s:.2f} traces={res_cold.traces} "
         f"speedup={percell_s / cold_s:.1f}x"),
        ("sweep/engine_warm", warm_s * 1e6 / (cells * iters),
         f"wall_s={warm_s:.2f} traces={res_warm.traces} "
         f"speedup={percell_s / warm_s:.1f}x"),
        ("sweep/grouped_cold", gcold_s * 1e6 / (cells * iters),
         f"wall_s={gcold_s:.2f} traces={res_gcold.traces}"),
        ("sweep/grouped_warm", gwarm_s * 1e6 / (cells * iters),
         f"wall_s={gwarm_s:.2f} traces={res_gwarm.traces} "
         f"vs_batched_warm={warm_s / gwarm_s:.2f}x"),
    ]
    return rows


def bucketed_ring_suite():
    """Bucketed pipelined ring vs per-leaf sequential rings, end-to-end step
    time on a simulated multi-host mesh.  Runs ``bucket_ring_bench.py`` in a
    subprocess (fake-device count must be set before jax initializes) and
    writes the full report to BENCH_dist.json."""
    script = os.path.join(os.path.dirname(__file__), "bucket_ring_bench.py")
    cmd = [sys.executable, script, "--workers", "4" if FAST else "8"]
    if FAST:
        cmd.append("--fast")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"bucket_ring_bench failed:\n{proc.stderr[-3000:]}")
    report = json.loads(proc.stdout)
    with open(BENCH_DIST_JSON, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    rows = []
    for wire in ("leaf", "bucketed"):
        w = report["wires"][wire]
        s8 = w["hlo_collective_bytes"].get("collective-permute/s8", 0)
        rows.append((f"bucket_ring/{wire}", w["step_us"],
                     f"hlo_s8_bytes={s8} compile_s={w['compile_s']}"))
    guard = report["wires"]["bucketed"]["wire_guard"]
    rows.append(("bucket_ring/speedup", 0.0,
                 f"bucketed_vs_leaf={report['speedup_bucketed_vs_leaf']}x "
                 f"wire_guard_ok={guard['ok']} rel_err={guard['rel_err']:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# legacy host-mesh optimizer-step suite (explicit-sharding jax API)
# ---------------------------------------------------------------------------

def _wire_bytes(params, variant, n_workers, s=1):
    """Analytic per-step inter-worker bytes per worker (uplink+downlink)."""
    total_f32 = sum(l.size * 4 for l in jax.tree.leaves(params))
    total_int8 = sum(l.size for l in jax.tree.leaves(params))
    scales = sum((l.size // l.shape[-1] if l.ndim else 1) * 4
                 for l in jax.tree.leaves(params))
    ring_f32 = 2 * (n_workers - 1) / n_workers * total_f32      # all-reduce
    ring_q = (n_workers - 1) * (total_int8 + scales) / n_workers
    if variant == "sgd":
        return ring_f32
    up = ring_q
    dwn = 0.0 if variant in ("biqsgd", "artemis") else ring_f32 / 2
    return up + dwn


def dist_step_suite():
    if not hasattr(jax.sharding, "AxisType") or not hasattr(jax, "set_mesh"):
        return [("dist_step/skipped", 0.0,
                 "needs jax explicit-sharding API (jax.sharding.AxisType)")]

    from repro import configs
    from repro.core import dist
    from repro.launch import mesh as M
    from repro.models.model import build_model
    from repro.optim import sgd

    rows = []
    mesh = M.make_host_mesh()
    cfg = configs.get_config("starcoder2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 128), 0,
                                          cfg.vocab)}
    n_workers = jax.device_count()
    with jax.set_mesh(mesh):
        for variant in ["none", "sgd", "qsgd", "artemis"]:
            dcfg = None if variant == "none" else dist.DistConfig(
                worker_axes=("data",), variant=variant)
            init_state, step_fn = dist.make_train_step(model, sgd(0.01), dcfg,
                                                       mesh)
            state = init_state(params)
            jstep = jax.jit(step_fn)
            state, out = jstep(state, batch)
            jax.block_until_ready(out)
            t0 = time.time()
            for _ in range(3):
                state, out = jstep(state, batch)
            jax.block_until_ready(out)
            us = (time.time() - t0) / 3 * 1e6
            wire = _wire_bytes(params, variant if variant != "none" else "sgd",
                               max(n_workers, 2))
            rows.append((f"dist_step/{variant}", us,
                         f"wire_bytes_per_worker={wire:.3e}"))
    return rows


ALL = [sweep_engine_suite, bucketed_ring_suite, dist_step_suite]
