"""Benchmarks reproducing each paper table/figure on the federated simulator.

Every grid now runs through the batched sweep engine (core.sweep.run_sweep):
one compiled program per figure instead of one trace per cell, with
monitoring thinned to an ``eval_every`` stride.

Each function returns a list of CSV rows: (name, us_per_call, derived) where
``us_per_call`` is wall-clock per simulated round per grid cell and
``derived`` carries the figure's headline quantity (saturation level, bits,
excess loss, ...).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import artemis as art
from repro.core import federated as fed
from repro.core import sweep as sw

KEY = jax.random.PRNGKey(123)
N, D = 20, 20

FAST = False      # set by benchmarks/run.py --fast: one cell, few iters


def _grid_size(res):
    return int(np.prod(res.losses.shape[:3]))


def _sweep_timed(prob, cfgs, gammas, iters, **kw):
    t0 = time.time()
    res = sw.run_sweep(prob, cfgs, gammas, kw.pop("seeds", [0]), iters, **kw)
    dt = time.time() - t0
    return res, dt * 1e6 / (iters * _grid_size(res))


def fig3a_saturation():
    """Fig 3a / S7: LSR i.i.d., sigma_* != 0 -> all variants saturate; double
    compression saturates above single, above SGD."""
    variants = ["sgd", "qsgd", "diana", "biqsgd", "artemis"]
    iters, tail = (300, 50) if FAST else (3000, 300)
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.4)
    opt = float(prob.global_loss(prob.solve_opt()))
    # one SHARED step size, stable for every variant (the bidirectional
    # gamma_max is the binding one) -> saturation ordering isolates E (Thm 1)
    gamma = 0.8 * fed.gamma_max(prob, art.variant_config("artemis", D, N))
    cfgs = [art.variant_config(v, D, N) for v in (variants[:1] if FAST else variants)]
    res, us = _sweep_timed(prob, cfgs, [gamma], iters, batch=1, eval_every=10)
    rows = []
    for vi, v in enumerate(variants[:len(cfgs)]):
        sat = float(np.mean(res.losses[vi, 0, 0, -tail // 10:])) - opt
        rows.append((f"fig3a/{v}", us, f"saturation={sat:.3e}"))
    return rows


def fig3b_memory_noniid():
    """Fig 3b / S9: non-i.i.d. logistic, full batch (sigma_*=0): memory
    converges linearly; memoryless saturates."""
    variants = ["biqsgd", "artemis", "qsgd", "diana", "sgd"]
    iters = 80 if FAST else 800
    prob = fed.make_logistic_problem(jax.random.PRNGKey(3), n_workers=N,
                                     n_per=200, d=2)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (2 * prob.smoothness())
    cfgs = [art.variant_config(v, 2, N) for v in (variants[:1] if FAST else variants)]
    res, us = _sweep_timed(prob, cfgs, [gamma], iters, full_batch=True,
                           eval_every=10)
    rows = []
    for vi, v in enumerate(variants[:len(cfgs)]):
        exc = float(res.losses[vi, 0, 0, -1]) - opt
        rows.append((f"fig3b/{v}", us, f"excess={exc:.3e}"))
    return rows


def fig4_bits():
    """Fig 4 / S11-S12: loss vs communicated bits on the clustered non-iid
    stand-in; bidirectional compression reaches target accuracy in ~10x fewer
    bits."""
    variants = ["sgd", "qsgd", "diana", "biqsgd", "artemis"]
    iters = 60 if FAST else 600
    prob = fed.make_clustered_problem(jax.random.PRNGKey(5), n_workers=N,
                                      n_per=300, d=40)
    opt = float(prob.global_loss(prob.solve_opt()))
    target = 0.5 * float(prob.global_loss(jnp.zeros(40)) - opt)
    gamma = 0.5 / prob.smoothness()
    cfgs = [art.variant_config(v, 40, N) for v in (variants[:1] if FAST else variants)]
    res, us = _sweep_timed(prob, cfgs, [gamma], iters, batch=16, eval_every=5)
    rows = []
    for vi, v in enumerate(variants[:len(cfgs)]):
        exc = res.losses[vi, 0, 0] - opt
        hit = np.argmax(exc < target) if (exc < target).any() else -1
        bits = res.bits[vi, 0, 0, hit] if hit >= 0 else float("inf")
        rows.append((f"fig4/{v}", us, f"bits_to_half_loss={bits:.3e}"))
    return rows


def fig56_partial_participation():
    """Fig 5 vs Fig 6: PP1 saturates even without compression; PP2 converges
    linearly (sigma_*=0, full gradients, non-iid).  All four (mode, variant)
    combinations ride ONE sweep: the pp_mode is just another branch."""
    iters, tail = (80, 5) if FAST else (800, 5)
    prob = fed.make_logistic_problem(jax.random.PRNGKey(7), n_workers=N,
                                     n_per=200, d=2)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (2 * prob.smoothness())
    combos = [("pp1", "sgd-mem"), ("pp1", "artemis"),
              ("pp2", "sgd-mem"), ("pp2", "artemis")]
    if FAST:
        combos = combos[:1]
    cfgs = [art.variant_config(v, 2, N, p=0.5, pp_mode=m) for m, v in combos]
    res, us = _sweep_timed(prob, cfgs, [gamma], iters, full_batch=True,
                           eval_every=10)
    rows = []
    for ci, (mode, variant) in enumerate(combos):
        exc = float(np.mean(res.losses[ci, 0, 0, -tail:])) - opt
        rows.append((f"fig56/{mode}/{variant}", us, f"excess={exc:.3e}"))
    return rows


def table3_gamma_max():
    """Table 3: the theoretical gamma_max is SUFFICIENT for convergence
    (validity); the doubling search for the empirical stability edge is now a
    VECTORIZED gamma axis — one sweep per variant instead of a Python loop."""
    iters = 40 if FAST else 400
    n_mults = 1 if FAST else 8
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.0)
    rows = []
    for variant in (["sgd"] if FAST else ["sgd", "qsgd", "artemis"]):
        cfg = art.variant_config(variant, D, N)
        g = fed.gamma_max(prob, cfg)
        mults = 2.0 ** np.arange(n_mults)              # 1x .. 128x
        res, us = _sweep_timed(prob, [cfg], g * mults, iters, batch=8,
                               eval_every=iters // 4)
        f0 = float(prob.global_loss(jnp.zeros(D)))     # loss at w0
        last = res.losses[0, :, 0, -1]
        ok = np.isfinite(last) & (last < f0)
        valid = bool(ok[0])
        edge = mults[np.argmin(ok)] / 2 if (~ok).any() else mults[-1]
        rows.append((f"table3/{variant}", us,
                     f"theory_gmax_converges={'yes' if valid else 'NO'} "
                     f"empirical/theory~{edge:.0f}x"))
    return rows


def thm3_variance_lower_bound():
    """Thm 3: asymptotic variance grows with omega_up (and omega_dwn):
    sparsification with smaller q (bigger omega) saturates strictly higher."""
    iters, tail = (80, 2) if FAST else (800, 10)
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.4)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (6 * prob.smoothness())
    qs = [1.0] if FAST else [1.0, 0.5, 0.25]
    cfgs = [art.ArtemisConfig(dim=D, n_workers=N, up="sparsify", dwn="sparsify",
                              up_kwargs={"q": q}, dwn_kwargs={"q": q},
                              alpha=0.0 if q == 1.0 else None)
            for q in qs]
    res, us = _sweep_timed(prob, cfgs, [gamma], iters, batch=1, eval_every=10)
    rows, sats = [], {}
    for qi, q in enumerate(qs):
        sats[q] = float(np.mean(res.losses[qi, 0, 0, -tail:])) - opt
        rows.append((f"thm3/sparsify_q={q}", us, f"saturation={sats[q]:.3e}"))
    if not FAST:
        rows.append(("thm3/monotone", 0.0,
                     f"omega_up_increases_variance="
                     f"{'yes' if sats[0.25] > sats[1.0] else 'NO'}"))
    return rows


ALL = [fig3a_saturation, fig3b_memory_noniid, fig4_bits,
       fig56_partial_participation, table3_gamma_max,
       thm3_variance_lower_bound]
