"""Benchmarks reproducing each paper table/figure on the federated simulator.

Each function returns a list of CSV rows: (name, us_per_call, derived) where
``derived`` carries the figure's headline quantity (saturation level, bits,
excess loss, ...).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import artemis as art
from repro.core import federated as fed

KEY = jax.random.PRNGKey(123)
N, D = 20, 20


def _timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def fig3a_saturation():
    """Fig 3a / S7: LSR i.i.d., sigma_* != 0 -> all variants saturate; double
    compression saturates above single, above SGD."""
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.4)
    opt = float(prob.global_loss(prob.solve_opt()))
    # one SHARED step size, stable for every variant (the bidirectional
    # gamma_max is the binding one) -> saturation ordering isolates E (Thm 1)
    gamma = 0.8 * fed.gamma_max(prob, art.variant_config("artemis", D, N))
    rows = []
    for variant in ["sgd", "qsgd", "diana", "biqsgd", "artemis"]:
        cfg = art.variant_config(variant, D, N)
        (r, us) = _timed(lambda: fed.run(prob, cfg, gamma=gamma, iters=3000,
                                         key=KEY, batch=1))
        sat = float(np.mean(r.losses[-300:])) - opt
        rows.append((f"fig3a/{variant}", us / 3000, f"saturation={sat:.3e}"))
    return rows


def fig3b_memory_noniid():
    """Fig 3b / S9: non-i.i.d. logistic, full batch (sigma_*=0): memory
    converges linearly; memoryless saturates."""
    prob = fed.make_logistic_problem(jax.random.PRNGKey(3), n_workers=N,
                                     n_per=200, d=2)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (2 * prob.smoothness())
    rows = []
    for variant in ["biqsgd", "artemis", "qsgd", "diana", "sgd"]:
        cfg = art.variant_config(variant, 2, N)
        (r, us) = _timed(lambda: fed.run(prob, cfg, gamma=gamma, iters=800,
                                         key=KEY, full_batch=True))
        exc = float(r.losses[-1]) - opt
        rows.append((f"fig3b/{variant}", us / 800, f"excess={exc:.3e}"))
    return rows


def fig4_bits():
    """Fig 4 / S11-S12: loss vs communicated bits on the clustered non-iid
    stand-in; bidirectional compression reaches target accuracy in ~10x fewer
    bits."""
    prob = fed.make_clustered_problem(jax.random.PRNGKey(5), n_workers=N,
                                      n_per=300, d=40)
    opt = float(prob.global_loss(prob.solve_opt()))
    target = 0.5 * float(prob.global_loss(jnp.zeros(40)) - opt)
    rows = []
    for variant in ["sgd", "qsgd", "diana", "biqsgd", "artemis"]:
        cfg = art.variant_config(variant, 40, N)
        gamma = 0.5 / prob.smoothness()
        (r, us) = _timed(lambda: fed.run(prob, cfg, gamma=gamma, iters=600,
                                         key=KEY, batch=16))
        exc = r.losses - opt
        hit = np.argmax(exc < target) if (exc < target).any() else -1
        bits = r.bits[hit] if hit >= 0 else float("inf")
        rows.append((f"fig4/{variant}", us / 600,
                     f"bits_to_half_loss={bits:.3e}"))
    return rows


def fig56_partial_participation():
    """Fig 5 vs Fig 6: PP1 saturates even without compression; PP2 converges
    linearly (sigma_*=0, full gradients, non-iid)."""
    prob = fed.make_logistic_problem(jax.random.PRNGKey(7), n_workers=N,
                                     n_per=200, d=2)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (2 * prob.smoothness())
    rows = []
    for mode in ["pp1", "pp2"]:
        for variant in ["sgd-mem", "artemis"]:
            cfg0 = art.variant_config(variant, 2, N, p=0.5, pp_mode=mode)
            (r, us) = _timed(lambda: fed.run(prob, cfg0, gamma=gamma, iters=800,
                                             key=KEY, full_batch=True))
            exc = float(np.mean(r.losses[-50:])) - opt
            rows.append((f"fig56/{mode}/{variant}", us / 800,
                         f"excess={exc:.3e}"))
    return rows


def table3_gamma_max():
    """Table 3: the theoretical gamma_max is SUFFICIENT for convergence
    (validity), and we measure how conservative it is via a doubling search
    for the empirical stability edge."""
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.0)
    rows = []
    for variant in ["sgd", "qsgd", "artemis"]:
        cfg = art.variant_config(variant, D, N)
        g = fed.gamma_max(prob, cfg)
        (r_ok, us) = _timed(lambda: fed.run(prob, cfg, gamma=g, iters=400,
                                            key=KEY, batch=8))
        ok = float(r_ok.losses[-1])
        valid = np.isfinite(ok) and ok < float(r_ok.losses[0])
        # doubling search for the empirical divergence edge
        mult = 1.0
        while mult <= 64:
            r = fed.run(prob, cfg, gamma=g * mult * 2, iters=400, key=KEY, batch=8)
            if not np.isfinite(r.losses[-1]) or r.losses[-1] > r.losses[0]:
                break
            mult *= 2
        rows.append((f"table3/{variant}", us / 400,
                     f"theory_gmax_converges={'yes' if valid else 'NO'} "
                     f"empirical/theory~{mult:.0f}x"))
    return rows


def thm3_variance_lower_bound():
    """Thm 3: asymptotic variance grows with omega_up (and omega_dwn):
    sparsification with smaller q (bigger omega) saturates strictly higher."""
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.4)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (6 * prob.smoothness())
    rows = []
    sats = {}
    for q in [1.0, 0.5, 0.25]:
        cfg = art.ArtemisConfig(dim=D, n_workers=N, up="sparsify", dwn="sparsify",
                                up_kwargs={"q": q}, dwn_kwargs={"q": q},
                                alpha=0.0 if q == 1.0 else None)
        (r, us) = _timed(lambda: fed.run(prob, cfg, gamma=gamma, iters=800,
                                         key=KEY, batch=1))
        sats[q] = float(np.mean(r.losses[-100:])) - opt
        rows.append((f"thm3/sparsify_q={q}", us / 800,
                     f"saturation={sats[q]:.3e}"))
    rows.append(("thm3/monotone", 0.0,
                 f"omega_up_increases_variance="
                 f"{'yes' if sats[0.25] > sats[1.0] else 'NO'}"))
    return rows


ALL = [fig3a_saturation, fig3b_memory_noniid, fig4_bits,
       fig56_partial_participation, table3_gamma_max,
       thm3_variance_lower_bound]
