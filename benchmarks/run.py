# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py [filter] [--fast] [--events PATH]
#
# ``--fast`` is the CI smoke mode: every suite shrinks to one grid cell and a
# handful of iterations, so the whole file finishes in well under a minute.
# ``--events PATH`` mirrors every CSV row into a schema-checked JSONL event
# log (repro.obs ``bench`` events) and wraps each suite in a profiling span,
# so benchmark runs land in the same sink the sweeps use.
from __future__ import annotations

import os
import sys
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    from benchmarks import dist_bench, fault_bench, kernel_bench, paper_figs

    from repro.obs import EventLog, span

    args = [a for a in sys.argv[1:]]
    fast = "--fast" in args
    if fast:
        args.remove("--fast")
        dist_bench.FAST = True
        paper_figs.FAST = True
        kernel_bench.FAST = True
        fault_bench.FAST = True
    events_path = None
    if "--events" in args:
        i = args.index("--events")
        events_path = args[i + 1]
        del args[i:i + 2]
    only = args[0] if args else None

    log = EventLog(events_path) if events_path else None
    if log is not None:
        log.start(config={"fast": fast, "filter": only},
                  fingerprint=f"bench:{'fast' if fast else 'full'}")

    # fault_bench last: it merges into the BENCH_dist.json that dist_bench's
    # bucketed-ring suite rewrites wholesale
    suites = paper_figs.ALL + kernel_bench.ALL + dist_bench.ALL + fault_bench.ALL
    print("name,us_per_call,derived")
    failures = 0
    for suite in suites:
        if only and only not in suite.__name__:
            continue
        try:
            with span(f"bench/{suite.__name__}"):
                rows = list(suite())
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
                if log is not None:
                    log.emit("bench", name=name, value=float(us),
                             unit="us_per_call", derived=str(derived))
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{suite.__name__},NaN,ERROR")
    if log is not None:
        log.end(status="fail" if failures else "ok")
        log.close()
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
