# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import dist_bench, kernel_bench, paper_figs

    suites = paper_figs.ALL + kernel_bench.ALL + dist_bench.ALL
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for suite in suites:
        if only and only not in suite.__name__:
            continue
        try:
            for name, us, derived in suite():
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{suite.__name__},NaN,ERROR")
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
