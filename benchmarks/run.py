# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py [filter] [--fast]
#
# ``--fast`` is the CI smoke mode: every suite shrinks to one grid cell and a
# handful of iterations, so the whole file finishes in well under a minute.
from __future__ import annotations

import os
import sys
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    from benchmarks import dist_bench, fault_bench, kernel_bench, paper_figs

    args = [a for a in sys.argv[1:]]
    fast = "--fast" in args
    if fast:
        args.remove("--fast")
        dist_bench.FAST = True
        paper_figs.FAST = True
        kernel_bench.FAST = True
        fault_bench.FAST = True
    only = args[0] if args else None

    # fault_bench last: it merges into the BENCH_dist.json that dist_bench's
    # bucketed-ring suite rewrites wholesale
    suites = paper_figs.ALL + kernel_bench.ALL + dist_bench.ALL + fault_bench.ALL
    print("name,us_per_call,derived")
    failures = 0
    for suite in suites:
        if only and only not in suite.__name__:
            continue
        try:
            for name, us, derived in suite():
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{suite.__name__},NaN,ERROR")
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
