"""§Perf hillclimb driver: run one (arch x shape x mesh x dist) combo with
config/dist overrides in a subprocess and print the three roofline terms.

  PYTHONPATH=src:. python -m benchmarks.hillclimb mistral-large-123b train_4k \
      multipod artemis remat_policy=dots_with_no_batch_dims_saveable
"""
from __future__ import annotations

import json
import subprocess
import sys
import tempfile


def run(arch, shape, mesh, dist, cfg_over=(), dist_over=()):
    with tempfile.NamedTemporaryFile(suffix=".json") as tf:
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--dist", dist,
               "--out", tf.name]
        for o in cfg_over:
            cmd += ["--cfg-override", o]
        for o in dist_over:
            cmd += ["--dist-override", o]
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=1800)
        try:
            rec = json.load(open(tf.name))[0]
        except Exception:
            return {"status": "error", "error": (proc.stderr or "?")[-400:]}
    return rec


def show(tag, rec):
    if rec.get("status") != "ok":
        print(f"{tag:58s} ERROR {rec.get('error','')[:120]}")
        return
    pk = (rec["memory_analysis"]["peak_bytes"] or 0) / 2**30
    print(f"{tag:58s} C={rec['compute_s']:.3f}s M={rec['memory_s']:.3f}s "
          f"X={rec['collective_s']:.3f}s dom={rec['dominant']:10s} "
          f"peak={pk:.1f}GiB useful={rec['useful_ratio']:.3f}")


if __name__ == "__main__":
    arch, shape, mesh, dist = sys.argv[1:5]
    overrides = sys.argv[5:]
    cfg_over = [o for o in overrides if not o.startswith("dist.")]
    dist_over = [o[5:] for o in overrides if o.startswith("dist.")]
    rec = run(arch, shape, mesh, dist, cfg_over, dist_over)
    show(f"{arch}x{shape}x{mesh}x{dist} {' '.join(overrides)}", rec)
