"""Roofline derivation unit tests: HLO collective parsing + flops accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import roofline as R

HLO = """
  %ag = f32[2,64,128]{2,1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = bf16[1024]{0} all-reduce(%y), to_apply=%sum
  %cp = s8[32,16]{1,0} collective-permute(%q), source_target_pairs={{0,1}}
  %rs = f32[512]{0} reduce-scatter(%z), dimensions={0}
  %a2a = f32[8,8]{1,0} all-to-all(%w), dimensions={0}
  %ags = s8[4,4]{1,0} all-gather-start(%v)
  %agd = s8[4,4]{1,0} all-gather-done(%ags)
"""


def test_collective_bytes_parsing():
    out = R.collective_bytes(HLO)
    assert out["all-gather"] == 2 * 64 * 128 * 4 + 4 * 4      # incl. -start
    assert out["all-reduce"] == 1024 * 2
    assert out["collective-permute"] == 32 * 16
    assert out["reduce-scatter"] == 512 * 4
    assert out["all-to-all"] == 64 * 4


def test_shape_bytes_tuple():
    assert R._shape_bytes("(f32[4,4], s8[8])") == 64 + 8


def test_collective_dtype_bytes_parsing():
    out = R.collective_dtype_bytes(HLO)
    assert out[("collective-permute", "s8")] == 32 * 16
    assert out[("all-gather", "f32")] == 2 * 64 * 128 * 4
    assert out[("all-gather", "s8")] == 4 * 4          # -start only, not -done
    assert out[("all-reduce", "bf16")] == 1024 * 2
    assert ("collective-permute", "f32") not in out


def test_bucketed_wire_model_accounting():
    m = R.bucketed_wire_model(n_workers=4, n_buckets=8, rows=33, row=256)
    assert m["hlo_s8_bytes"] == 8 * 33 * 256
    assert m["hlo_scale_bytes"] == 4 * 8 * 33
    assert m["wire_bytes_per_step"] == 3 * m["payload_bytes"]
    # pipelining can only help, and the exposed time is what overlap leaves
    assert m["step_comm_pipelined_s"] <= m["step_comm_serial_s"]
    assert m["exposed_comm_s"] <= m["comm_s"]
    # compute-bound regime: wire so fast the dequant fully hides it
    fast = R.bucketed_wire_model(n_workers=4, n_buckets=8, rows=33, row=256,
                                 ici_bw=1e18, coll_lat=0.0)
    assert fast["exposed_comm_s"] == 0.0


def test_leaf_wire_model_accounting():
    shapes = [(64, 64), (64,), (64, 1)]
    m = R.leaf_wire_model(shapes, n_workers=4)
    payload_level = 64 * 64 + 64 + 64
    assert m["hlo_s8_bytes"] == 3 * payload_level     # unrolled hops in HLO
    assert m["wire_bytes_per_step"] == 3 * m["payload_bytes"]
    # nothing overlaps on the leaf path
    assert m["step_comm_pipelined_s"] == m["step_comm_serial_s"]
    # same bytes, but the per-leaf latency term makes it slower than bucketed
    b = R.bucketed_wire_model(n_workers=4, n_buckets=1,
                              rows=payload_level // 64, row=64)
    assert m["comm_s"] > b["comm_s"]


def test_wire_bytes_match_guard():
    m = {"hlo_s8_bytes": 32 * 16}
    ok = R.wire_bytes_match(HLO, m)
    assert ok["ok"] and ok["rel_err"] == 0.0
    bad = R.wire_bytes_match(HLO, {"hlo_s8_bytes": 32 * 16 * 2})
    assert not bad["ok"] and bad["rel_err"] == pytest.approx(0.5)
    none = R.wire_bytes_match("", m)
    assert not none["ok"]                  # zero measured s8 never passes


def test_bucketed_wire_model_from_codec():
    """Passing a core/codec.py codec derives the byte split from its
    wire_bytes — identical to the legacy analytic split for row_squant, and
    a genuinely different wire (s32 indices + f32 values) for sparsify."""
    from repro.core import codec as wire
    rq = wire.make_codec("row_squant", 256, s=3)
    m_codec = R.bucketed_wire_model(n_workers=4, n_buckets=8, rows=33,
                                    row=256, codec=rq)
    m_legacy = R.bucketed_wire_model(n_workers=4, n_buckets=8, rows=33,
                                     row=256)
    for k in ("payload_bytes", "hlo_s8_bytes", "hlo_scale_bytes",
              "wire_bytes_per_step", "comm_s", "exposed_comm_s"):
        assert m_codec[k] == m_legacy[k], k
    assert m_codec["hlo_bytes_by_dtype"] == {"s8": 8 * 33 * 256.0,
                                             "f32": 8 * 4 * 33.0}

    sp = wire.make_codec("sparsify", 256, q=0.5)
    m_sp = R.bucketed_wire_model(n_workers=4, n_buckets=8, rows=33, row=256,
                                 codec=sp)
    n = 33 * 256
    assert m_sp["hlo_bytes_by_dtype"] == {"s32": 8 * 4.0 * n,
                                          "f32": 8 * 4.0 * n}
    assert m_sp["hlo_s8_bytes"] == 0.0


def test_leaf_wire_model_from_codec():
    from repro.core import codec as wire
    shapes = [(64, 64), (64,), (64, 1)]
    rq = wire.make_codec("row_squant", 64, s=3)
    m_codec = R.leaf_wire_model(shapes, n_workers=4, codec=rq)
    m_legacy = R.leaf_wire_model(shapes, n_workers=4)
    for k in ("payload_bytes", "hlo_s8_bytes", "hlo_scale_bytes", "comm_s"):
        assert m_codec[k] == m_legacy[k], k


def test_wire_bytes_match_per_dtype():
    """Codec-derived models check EVERY payload dtype, not just s8."""
    m = {"hlo_bytes_by_dtype": {"s8": 32 * 16.0}}
    ok = R.wire_bytes_match(HLO, m)
    assert ok["ok"] and ok["by_dtype"]["s8"]["rel_err"] == 0.0
    # a dtype the HLO does not carry fails the guard
    m2 = {"hlo_bytes_by_dtype": {"s8": 32 * 16.0, "s32": 1024.0}}
    assert not R.wire_bytes_match(HLO, m2)["ok"]
    # byte mismatch on a present dtype fails too
    m3 = {"hlo_bytes_by_dtype": {"s8": 32 * 16 * 2.0}}
    bad = R.wire_bytes_match(HLO, m3)
    assert not bad["ok"] and bad["rel_err"] == pytest.approx(0.5)


def test_roofline_terms_and_dominant():
    rl = R.Roofline(arch="a", shape="s", mesh="pod", chips=256, kind="train",
                    hlo_flops=197e12, hlo_bytes=819e9 * 2,
                    coll_bytes={"all-reduce": int(50e9 * 0.5)},
                    model_flops=100e12).finalize()
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(2.0)
    assert rl.collective_s == pytest.approx(0.5)
    assert rl.dominant == "memory"


def test_active_params_moe():
    cfg = configs.get_config("olmoe-1b-7b", reduced=True)
    from repro.models.model import build_model
    params = jax.eval_shape(lambda: build_model(cfg).init(jax.random.PRNGKey(0)))
    total = R.count_params(params)
    active = R.active_params(cfg, params)
    assert active < total                     # top-2 of 4 experts
    # expert fraction scales by top_k/n_experts = 1/2
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    expert = sum(int(np.prod(l.shape)) for p, l in flat
                 if "moe" in "/".join(str(getattr(q, 'key', q)) for q in p)
                 and "router" not in "/".join(str(getattr(q, 'key', q)) for q in p))
    assert active == pytest.approx(total - expert + expert * 0.5)


def test_model_flops_conventions():
    cfg = configs.get_config("starcoder2-7b", reduced=True)
    from repro.models.model import build_model
    params = jax.eval_shape(lambda: build_model(cfg).init(jax.random.PRNGKey(0)))
    n = R.active_params(cfg, params)
    assert R.model_flops(cfg, params, "train", 2, 8) == 6 * n * 16
    assert R.model_flops(cfg, params, "prefill", 2, 8) == 2 * n * 16
    assert R.model_flops(cfg, params, "decode", 2, 8) == 2 * n * 2


def test_input_specs_shapes():
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        for sname, shape in configs.SHAPES.items():
            if configs.applicable(cfg, shape):
                continue
            specs = configs.input_specs(cfg, shape)
            if shape.kind in ("train", "prefill"):
                if cfg.family == "vlm":
                    assert specs["tokens"].shape == (shape.batch,
                                                     shape.seq - cfg.n_patches)
                    assert specs["embeds"].shape[1] == cfg.n_patches
                else:
                    assert specs["tokens"].shape == (shape.batch, shape.seq)
            else:
                assert specs["token"].shape == (shape.batch,)
                assert len(jax.tree.leaves(specs["cache"])) > 0
