"""MoE routing correctness: forward and custom-VJP gradients vs a naive
gather-based reference (identical math when capacity is dropless)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe

KEY = jax.random.PRNGKey(0)
G_, T_, D_, F_, E_, K_ = 2, 16, 8, 12, 4, 2


def _naive_moe(p, x, top_k, activation="silu"):
    """Dropless reference: every token reaches its top-k experts (computed
    densely per expert with masks — no capacity, no scatter)."""
    b, s, d = x.shape
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = (gate / jnp.sum(gate, -1, keepdims=True)).astype(x.dtype)
    out = jnp.zeros_like(x)
    n_experts = p["router"].shape[1]
    for e in range(n_experts):
        if activation == "silu":
            h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        else:
            h = jax.nn.gelu(x @ p["w_up"][e])
        y = h @ p["w_down"][e]
        w = jnp.sum(jnp.where(idx == e, gate, 0.0), axis=-1)
        out = out + y * w[..., None]
    return out


@pytest.fixture(scope="module")
def setup():
    p = moe.init_moe(KEY, D_, F_, E_, "silu")
    x = jax.random.normal(jax.random.PRNGKey(1), (G_, T_, D_))
    return p, x


def test_forward_matches_naive(setup):
    p, x = setup
    out, _ = moe.moe_apply(p, x, top_k=K_, capacity_factor=float(E_),
                           group_size=T_)
    ref = _naive_moe(p, x, K_)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_grads_match_naive(setup):
    """The scatter-only custom VJP must agree with autodiff of the naive path."""
    p, x = setup

    def loss_fast(p, x):
        out, _ = moe.moe_apply(p, x, top_k=K_, capacity_factor=float(E_),
                               group_size=T_)
        return jnp.sum(out * jnp.cos(jnp.arange(D_)))

    def loss_ref(p, x):
        return jnp.sum(_naive_moe(p, x, K_) * jnp.cos(jnp.arange(D_)))

    g1 = jax.grad(loss_fast, argnums=(0, 1))(p, x)
    g2 = jax.grad(loss_ref, argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_capacity_drops_tokens(setup):
    """With capacity 1x and adversarially unbalanced routing, some tokens are
    dropped (pass through residual as zeros) rather than crashing."""
    p, x = setup
    # bias router hard toward expert 0
    p2 = dict(p, router=p["router"].at[:, 0].add(100.0))
    out, _ = moe.moe_apply(p2, x, top_k=K_, capacity_factor=1.0, group_size=T_)
    assert np.isfinite(np.asarray(out)).all()


def test_aux_loss_balanced_lower(setup):
    p, x = setup
    _, aux_bal = moe.moe_apply(p, x, top_k=K_, group_size=T_)
    p2 = dict(p, router=p["router"].at[:, 0].add(100.0))
    _, aux_skew = moe.moe_apply(p2, x, top_k=K_, group_size=T_)
    assert float(aux_skew) > float(aux_bal)


def test_grad_through_capacity_drop(setup):
    """Gradients stay finite when tokens are dropped."""
    p, x = setup

    def loss(p, x):
        out, aux = moe.moe_apply(p, x, top_k=K_, capacity_factor=1.0,
                                 group_size=T_)
        return jnp.mean(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p, x)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
