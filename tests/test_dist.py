"""Distributed-Artemis tests. Each scenario runs in a subprocess with 8 fake
CPU devices (XLA device count is locked at first jax init, so it cannot be
set inside this pytest process)."""
import os
import subprocess
import sys

import jax.sharding
import pytest

pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="dist scenarios need jax.sharding.AxisType (jax >= 0.5 explicit-"
           "sharding API); not available in this jax build")

HELPER = os.path.join(os.path.dirname(__file__), "helpers", "dist_scenarios.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCENARIOS = [
    "convergence",
    "sgd_variant_matches_baseline",
    "all_variants_lower",
    "partial_participation",
    "int8_ring_in_hlo",
    "mesh_policy",
    "pipeline_sharding",
    "dore_and_local_steps",
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_scenario(scenario):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, HELPER, scenario],
                          capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, f"\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    assert f"scenario {scenario}: OK" in proc.stdout
