"""Bucketed-wire transport tests. Each scenario runs in a subprocess with 8
fake CPU devices (XLA device count is locked at first jax init).

No AxisType skip here: the bucketed wire goes through
``dist.shard_map_compat`` / ``dist.make_worker_mesh``, which work on both
jax API generations — these scenarios are the dist coverage that always runs.
"""
import os
import subprocess
import sys

import pytest

HELPER = os.path.join(os.path.dirname(__file__), "helpers",
                      "bucket_scenarios.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCENARIOS = [
    "ring_matches_psum",
    "ring_bitwise",
    "ef_pp_inactive_zero",
    "hlo_wire_guard",
    "bucketed_convergence",
    "fault_zero_bitwise",
    "fault_matrix",
    "codec_sparsify",
    "codec_wire_guard",
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_scenario(scenario):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, HELPER, scenario],
                          capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, f"\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    assert f"scenario {scenario}: OK" in proc.stdout
