"""Property-test shim: re-export hypothesis when available, otherwise a
small seeded-random fallback so the property checks still run (with fixed,
deterministic examples) when the dependency is missing.

Usage in tests (drop-in for ``from hypothesis import ...``):

    from helpers.prop import given, settings, st, HAVE_HYPOTHESIS
"""
from __future__ import annotations

import functools
import inspect
import random

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A sampler: draw(rng) -> one example."""

        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimic the hypothesis.strategies namespace
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    # Without hypothesis's shared-shape shrinking/caching, every drawn example
    # tends to be a fresh jit compile on this suite — cap the fallback count
    # so the property checks stay cheap (hypothesis, when installed, runs the
    # full ``max_examples``).
    _DEFAULT_EXAMPLES = 3

    def given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0xA27E715)  # deterministic across runs
                n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                        _DEFAULT_EXAMPLES)
                for _ in range(n):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # hide ONLY the drawn params from pytest's fixture resolution;
            # leftover params (pytest.mark.parametrize args) stay visible
            del wrapper.__dict__["__wrapped__"]
            params = list(inspect.signature(fn).parameters.values())
            if strategies:   # positional strategies consume trailing params
                params = params[:-len(strategies)]
            params = [p for p in params if p.name not in kw_strategies]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
