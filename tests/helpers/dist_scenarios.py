"""Multi-device test scenarios (run in a subprocess with 8 fake CPU devices).

Invoked by tests/test_dist.py as:
    python tests/helpers/dist_scenarios.py <scenario>
Exits non-zero on assertion failure.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import dist
from repro.launch import mesh as M
from repro.models.model import build_model
from repro.optim import sgd


def _setup(variant="artemis", worker_axes=("pod",), s=3, p=1.0):
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = configs.get_config("starcoder2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = None if variant == "none" else dist.DistConfig(
        worker_axes=worker_axes, variant=variant, s=s, p_participation=p)
    pshard = M.params_shardings(mesh, params)
    banned = dcfg.worker_axes if dcfg else ()
    gspecs = (jax.tree.map(lambda ns: M.strip_axes(ns.spec, banned), pshard)
              if dcfg else None)
    init_state, step_fn = dist.make_train_step(model, sgd(0.05), dcfg, mesh,
                                               grad_specs=gspecs)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 128), 0,
                                          cfg.vocab)}
    return mesh, model, params, init_state, step_fn, batch


def scenario_convergence():
    mesh, model, params, init_state, step_fn, batch = _setup("artemis")
    with jax.set_mesh(mesh):
        state = init_state(params)
        jstep = jax.jit(step_fn)
        losses = []
        for _ in range(12):
            state, (loss, _) = jstep(state, batch)
            losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses
    assert all(np.isfinite(l) for l in losses)
    # memory engaged: h moved away from zero
    hn = sum(float(jnp.sum(jnp.square(l))) for l in jax.tree.leaves(state.artemis.h))
    assert hn > 0


def scenario_sgd_variant_matches_baseline():
    """variant='sgd' over pod (explicit psum) == dcfg=None baseline (XLA)."""
    out = {}
    for tag, variant in [("explicit", "sgd"), ("baseline", "none")]:
        mesh, model, params, init_state, step_fn, batch = _setup(variant)
        with jax.set_mesh(mesh):
            state = init_state(params)
            jstep = jax.jit(step_fn)
            for _ in range(3):
                state, (loss, _) = jstep(state, batch)
            out[tag] = float(loss)
    assert abs(out["explicit"] - out["baseline"]) < 5e-3, out


def scenario_all_variants_lower():
    for variant in ["qsgd", "diana", "biqsgd", "artemis"]:
        mesh, model, params, init_state, step_fn, batch = _setup(variant)
        with jax.set_mesh(mesh):
            state = init_state(params)
            state, (loss, _) = jax.jit(step_fn)(state, batch)
            assert np.isfinite(float(loss)), variant


def scenario_partial_participation():
    mesh, model, params, init_state, step_fn, batch = _setup("artemis", p=0.5)
    with jax.set_mesh(mesh):
        state = init_state(params)
        jstep = jax.jit(step_fn)
        losses = [float(jstep(state, batch)[1][0])]
        for _ in range(15):
            state, (loss, _) = jstep(state, batch)
            losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def scenario_int8_ring_in_hlo():
    """The compiled HLO must move int8 (not f32) across the worker axis."""
    import re
    mesh, model, params, init_state, step_fn, batch = _setup("artemis")
    with jax.set_mesh(mesh):
        state = init_state(params)
        hlo = jax.jit(step_fn).lower(state, batch).compile().as_text()
    perms = re.findall(r"= (\w+)\[[0-9,]*\][^ ]* collective-permute", hlo)
    assert any(d == "s8" for d in perms), perms


def scenario_mesh_policy():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    # big matrices: 2-D sharded
    assert M.param_spec(mesh, "layers/mlp/w_up", (4, 256, 512)) == \
        P(None, "data", "model")
    # embed: vocab unsharded
    assert M.param_spec(mesh, "embed", (1000, 256)) == P(None, "model")
    # moe experts over model when divisible
    assert M.param_spec(mesh, "layers/moe/w_up", (4, 8, 256, 512)) == \
        P(None, "model", "data", None)
    # non-divisible expert count falls back to 2-D weight sharding
    assert M.param_spec(mesh, "layers/moe/w_up", (4, 3, 256, 512)) == \
        P(None, None, "data", "model")
    # non-divisible dims left unsharded
    assert M.param_spec(mesh, "layers/mlp/w_up", (4, 255, 513)) == P(None, None, None)
    # strip_axes removes manual axes
    assert M.strip_axes(P("pod", "data"), ("pod",)) == P(None, "data")
    assert M.strip_axes(P(("pod", "data"), None), ("pod",)) == P(("data",), None)


def scenario_pipeline_sharding():
    from repro.data.pipeline import ShardedBatches
    from repro.data.synthetic import TokenStream, TokenStreamConfig
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    stream = TokenStream(TokenStreamConfig(vocab=64, seq_len=16, batch=8))
    sb = ShardedBatches(stream, mesh)
    b = sb.batch_at(0)
    assert b["tokens"].shape == (8, 16)
    assert b["tokens"].sharding.spec == P(("pod", "data"))
    # determinism
    b2 = sb.batch_at(0)
    assert jnp.array_equal(b["tokens"], b2["tokens"])


def scenario_dore_and_local_steps():
    """Beyond-paper variants: Dore-style EF and local-step accumulation both
    converge; the local (non-communicating) step's HLO has NO collectives."""
    import re
    from repro.core.dist import make_local_step
    mesh, model, params, init_state, step_fn, batch = _setup("dore")
    with jax.set_mesh(mesh):
        state = init_state(params)
        jstep = jax.jit(step_fn)
        l0 = float(jstep(state, batch)[1][0])
        for _ in range(8):
            state, (loss, _) = jstep(state, batch)
        assert float(loss) < l0
        en = sum(float(jnp.sum(jnp.square(l)))
                 for l in jax.tree.leaves(state.artemis.e))
        assert en > 0, "EF buffer never engaged"

    mesh, model, params, init_state, step_fn, batch = _setup("artemis")
    dcfg = dist.DistConfig(worker_axes=("pod",), variant="artemis", s=3,
                           local_steps=4)
    init_state, step_fn = dist.make_train_step(model, sgd(0.05), dcfg, mesh)
    local_fn = make_local_step(model, dcfg, mesh)
    with jax.set_mesh(mesh):
        state = init_state(params)
        hlo = jax.jit(local_fn).lower(state, batch).compile().as_text()
        colls = re.findall(r"(all-reduce|all-gather|collective-permute|"
                           r"reduce-scatter|all-to-all)\(", hlo)
        assert not colls, f"local step must not communicate: {colls[:5]}"


if __name__ == "__main__":
    name = sys.argv[1]
    globals()[f"scenario_{name}"]()
    print(f"scenario {name}: OK")
