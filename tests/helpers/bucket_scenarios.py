"""Bucketed-wire mesh scenarios (run in a subprocess with 8 fake CPU devices).

Unlike tests/helpers/dist_scenarios.py (which exercises the production
partial-manual mesh and needs the new-jax explicit-sharding API), these run
on worker-only meshes through ``dist.shard_map_compat`` and therefore work on
BOTH jax API generations — the bucketed transport is tested everywhere.

Invoked by tests/test_bucketed.py as:
    python tests/helpers/bucket_scenarios.py <scenario>
Exits non-zero on assertion failure.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketing, dist, faults
from repro.launch import roofline
from repro.models.toy import ToyMLP
from repro.optim import sgd

VARIANTS = list(dist.VARIANTS)


def _setup(variant="artemis", *, wire="bucketed", reduce_impl="pipelined",
           mesh_shape=(2, 2), axes=("p", "q"), p=1.0, s=3,
           bucket_bytes=4096, max_buckets=8, row=64, local_steps=1,
           error_feedback=False, fault_cfg=None,
           codec="squant", codec_kwargs=()):
    mesh = dist.make_worker_mesh(mesh_shape, axes)
    model = ToyMLP(n_layers=4, d=64)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = dist.DistConfig(worker_axes=axes, variant=variant, s=s,
                           p_participation=p, wire=wire,
                           reduce_impl=reduce_impl, bucket_bytes=bucket_bytes,
                           max_buckets=max_buckets, bucket_row=row,
                           local_steps=local_steps,
                           error_feedback=error_feedback, faults=fault_cfg,
                           codec=codec, codec_kwargs=tuple(codec_kwargs))
    init_state, step_fn = dist.make_train_step(model, sgd(0.05), dcfg, mesh)
    batch = model.batch(jax.random.PRNGKey(1), n=32)
    return mesh, model, params, dcfg, init_state, step_fn, batch


def _run(variant, steps=3, **kw):
    _, _, params, _, init_state, step_fn, batch = _setup(variant, **kw)
    state = init_state(params)
    jstep = jax.jit(step_fn)
    loss = None
    for _ in range(steps):
        state, (loss, _) = jstep(state, batch)
    return state, float(loss)


def scenario_ring_matches_psum():
    """Satellite: every variant's pipelined bucketed ring == jax.lax.psum of
    the dequantized payloads (the dense reference) to 1e-5 on a 2x2 mesh."""
    for variant in VARIANTS:
        out = {}
        for impl in ("pipelined", "psum"):
            state, loss = _run(variant, reduce_impl=impl)
            out[impl] = (jax.tree.map(np.asarray, state.params), loss)
        for pl, ps in zip(jax.tree.leaves(out["pipelined"][0]),
                          jax.tree.leaves(out["psum"][0])):
            np.testing.assert_allclose(pl, ps, atol=1e-5, err_msg=variant)
        assert abs(out["pipelined"][1] - out["psum"][1]) < 1e-5, variant


def scenario_ring_bitwise():
    """The pipelined scan ring matches the sequential unrolled transport
    (the pre-bucketing schedule applied to the same payload) BIT-FOR-BIT —
    both multi-bucket and the degenerate buckets=1 / bucket_bytes=inf
    layout, which is the leaf-loop wire collapsed to one message."""
    grids = [dict(),                                        # multi-bucket
             dict(bucket_bytes=1 << 40, max_buckets=1)]     # B=1, elems=all
    for kw in grids:
        out = {}
        for impl in ("pipelined", "sequential"):
            state, loss = _run("artemis", reduce_impl=impl, **kw)
            out[impl] = jax.tree.map(np.asarray, state.params)
        for a, b in zip(jax.tree.leaves(out["pipelined"]),
                        jax.tree.leaves(out["sequential"])):
            np.testing.assert_array_equal(a, b, err_msg=str(kw))


def scenario_ef_pp_inactive_zero():
    """Satellite (EF + PP2 leak fix): a round where every worker is inactive
    must change params by EXACTLY zero and leave the EF buffers untouched —
    previously the inactive worker's e kept riding the compressed uplink.
    Checked on BOTH wires (the fix is `scale *= active` in each)."""
    for wire in dist.WIRES:
        _, _, params, _, init_state, step_fn, batch = _setup(
            "dore", wire=wire, p=1e-9)
        state = init_state(params)
        e0 = jax.tree.map(lambda e: jnp.full_like(e, 0.3), state.artemis.e)
        state = state._replace(artemis=state.artemis._replace(e=e0))
        new, (loss, _) = jax.jit(step_fn)(state, batch)
        assert np.isfinite(loss), wire
        for p0, p1 in zip(jax.tree.leaves(state.params),
                          jax.tree.leaves(new.params)):
            np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1),
                                          err_msg=wire)
        for a, b in zip(jax.tree.leaves(e0), jax.tree.leaves(new.artemis.e)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=wire)
        for a, b in zip(jax.tree.leaves(state.artemis.h),
                        jax.tree.leaves(new.artemis.h)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=wire)


def scenario_hlo_wire_guard():
    """Satellite (CI wire-format guard): lower the bucketed train step on a
    4-worker mesh and pin the s8 collective-permute bytes to the roofline
    model within 10%."""
    mesh, model, params, dcfg, init_state, step_fn, batch = _setup(
        "artemis", mesh_shape=(4,), axes=("pod",))
    state = init_state(params)
    hlo = jax.jit(step_fn).lower(state, batch).compile().as_text()
    lay = dcfg.layout(params)
    model_b = roofline.bucketed_wire_model(
        n_workers=4, n_buckets=lay.n_buckets, rows=lay.rows, row=lay.row)
    res = roofline.wire_bytes_match(hlo, model_b)
    assert res["ok"], res
    # scales ride as f32 — present but small next to the s8 payload
    assert 0 < res["measured_scale_f32"] < res["measured_s8"], res


def scenario_bucketed_convergence():
    """All variants train finite on the bucketed wire; artemis converges;
    dore engages its EF buffer; the bucketed local (non-communicating) step
    compiles to ZERO collectives."""
    for variant in VARIANTS:
        state, loss = _run(variant, steps=1)
        assert np.isfinite(loss), variant

    _, _, params, _, init_state, step_fn, batch = _setup("artemis")
    state = init_state(params)
    jstep = jax.jit(step_fn)
    losses = []
    for _ in range(10):
        state, (loss, _) = jstep(state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert float(jnp.sum(jnp.square(state.artemis.h))) > 0

    state, _ = _run("dore", steps=5)
    assert float(jnp.sum(jnp.square(state.artemis.e))) > 0, "EF never engaged"

    mesh, model, params, dcfg, init_state, _, batch = _setup(
        "artemis", local_steps=4)
    local_fn = dist.make_local_step(model, dcfg, mesh)
    state = init_state(params)
    hlo = jax.jit(local_fn).lower(state, batch).compile().as_text()
    colls = re.findall(r"(all-reduce|all-gather|collective-permute|"
                       r"reduce-scatter|all-to-all)\(", hlo)
    assert not colls, f"bucketed local step must not communicate: {colls[:5]}"


def scenario_fault_zero_bitwise():
    """Zero-fault identity on the mesh backend: DistConfig(faults=
    FaultConfig()) — every rate zero, defenses off — produces bit-identical
    trajectories to faults=None on BOTH wires.  The fault paths are all
    statically gated and their PRNG streams salted, so the config's mere
    presence must not move a bit."""
    for wire in dist.WIRES:
        out = {}
        for fc in (None, faults.FaultConfig()):
            state, loss = _run("artemis", wire=wire, p=0.5, fault_cfg=fc)
            out[fc is None] = (jax.tree.map(np.asarray, state.params), loss)
        for a, b in zip(jax.tree.leaves(out[True][0]),
                        jax.tree.leaves(out[False][0])):
            np.testing.assert_array_equal(a, b, err_msg=wire)
        assert out[True][1] == out[False][1], wire


def scenario_fault_matrix():
    """Fault matrix x both wires: wire bit-flips, NaN gradient blowups, and
    a straggler burst over sticky Markov participation — each with server
    scrubbing on — must keep training finite (corrupt => inactive via the
    PP2 zero-scale path)."""
    matrix = {
        "bitflip": faults.FaultConfig(bitflip_rate=0.02, scrub=True),
        "nan_blowup": faults.FaultConfig(blowup_rate=0.5, scrub=True),
        "dropout_burst": faults.FaultConfig(straggler_rate=0.5, p_stay=0.8,
                                            scrub=True),
    }
    for wire in dist.WIRES:
        for name, fc in matrix.items():
            state, loss = _run("artemis", wire=wire, p=0.5, steps=4,
                               fault_cfg=fc)
            assert np.isfinite(loss), (wire, name, loss)
            for leaf in jax.tree.leaves(state.params):
                assert np.all(np.isfinite(np.asarray(leaf))), (wire, name)


def scenario_codec_sparsify():
    """Tentpole: a non-quantizer codec rides the SAME bucketed transport.
    ``codec="sparsify"`` ships (int32 indices, f32 values) payloads through
    the pipelined ring — training stays finite and converges, the pipelined
    ring matches psum of the decoded payloads, and the EF variant engages."""
    kw = dict(codec="sparsify", codec_kwargs=(("q", 0.5),))
    out = {}
    for impl in ("pipelined", "psum"):
        state, loss = _run("artemis", reduce_impl=impl, **kw)
        out[impl] = (jax.tree.map(np.asarray, state.params), loss)
        assert np.isfinite(loss), impl
    for pl, ps in zip(jax.tree.leaves(out["pipelined"][0]),
                      jax.tree.leaves(out["psum"][0])):
        np.testing.assert_allclose(pl, ps, atol=1e-5)

    _, _, params, _, init_state, step_fn, batch = _setup("artemis", **kw)
    state = init_state(params)
    jstep = jax.jit(step_fn)
    losses = []
    for _ in range(10):
        state, (loss, _) = jstep(state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses

    state, loss = _run("dore", steps=4, **kw)
    assert np.isfinite(loss)
    assert float(jnp.sum(jnp.square(state.artemis.e))) > 0, "EF never engaged"


def scenario_codec_wire_guard():
    """Tentpole (roofline from wire_bytes): for BOTH registered mesh codecs,
    lower the bucketed step on a 4-worker mesh and check every payload dtype's
    collective-permute bytes against the codec-derived roofline model."""
    from repro.core import codec as wire
    for name, kwargs in (("squant", (("s", 3),)), ("sparsify", (("q", 0.5),))):
        mesh, model, params, dcfg, init_state, step_fn, batch = _setup(
            "artemis", mesh_shape=(4,), axes=("pod",),
            codec=name, codec_kwargs=kwargs)
        state = init_state(params)
        hlo = jax.jit(step_fn).lower(state, batch).compile().as_text()
        lay = dcfg.layout(params)
        wc = dcfg.wire_codec(lay.row)
        model_b = roofline.bucketed_wire_model(
            n_workers=4, n_buckets=lay.n_buckets, rows=lay.rows, row=lay.row,
            codec=wc)
        res = roofline.wire_bytes_match(hlo, model_b)
        assert res["ok"], (name, res)


def scenario_obs_wire_telemetry():
    """Observability (repro.obs): DistConfig(telemetry=True) attaches a
    psum'd ``obs`` dict to the step metrics whose per-worker wire_bytes
    matches the codec-derived roofline model EXACTLY on both ring wires
    (bucketed + leaf); the psum fallback reports its dense-f32 proxy; and
    telemetry is loss-neutral + absent from metrics when off.  The measured
    vs model numbers round-trip as schema-valid ``wire`` events."""
    import dataclasses
    import tempfile

    from repro.obs import events as obs_events
    from repro.optim import sgd as _sgd

    log_path = tempfile.mktemp(suffix=".jsonl")
    with obs_events.EventLog(log_path) as log:
        for wire, impl in [("bucketed", "pipelined"),
                           ("bucketed", "sequential"),
                           ("bucketed", "psum"),
                           ("leaf", "sequential")]:
            mesh, model, params, dcfg, init_state, step_fn, batch = _setup(
                "artemis", wire=wire, reduce_impl=impl,
                mesh_shape=(4,), axes=("pod",))
            dcfg_t = dataclasses.replace(dcfg, telemetry=True)
            init_t, step_t = dist.make_train_step(model, _sgd(0.05),
                                                  dcfg_t, mesh)
            state, (loss, m) = jax.jit(step_t)(init_t(params), batch)
            assert "obs" in m, (wire, impl, sorted(m))
            obs = {k: float(v) for k, v in m["obs"].items()}
            assert obs["mesh_active"] == 4.0, obs
            # telemetry off: no obs key, identical loss
            _, (loss0, m0) = jax.jit(step_fn)(init_state(params), batch)
            assert "obs" not in m0, (wire, impl)
            assert float(loss0) == float(loss), (wire, impl)
            if wire == "bucketed":
                lay = dcfg.layout(params)
                wm = roofline.bucketed_wire_model(
                    n_workers=4, n_buckets=lay.n_buckets, rows=lay.rows,
                    row=lay.row, codec=dcfg.wire_codec(lay.row))
            else:
                shapes = [tuple(l.shape) for l in jax.tree.leaves(params)]
                wm = roofline.leaf_wire_model(shapes, n_workers=4,
                                              codec=dcfg.wire_codec(64))
            per_worker = obs["wire_bytes"] / 4.0
            log.emit("wire", wire=wire, reduce_impl=impl,
                     measured_bytes=per_worker,
                     model_bytes=wm["wire_bytes_per_step"])
            if impl == "psum":          # dense all-reduce proxy, not a ring
                assert per_worker > wm["wire_bytes_per_step"], (wire, impl)
            else:
                assert per_worker == wm["wire_bytes_per_step"], (
                    wire, impl, per_worker, wm["wire_bytes_per_step"])
    evs = obs_events.read_events(log_path)
    assert len(evs) == 4
    assert obs_events.validate_events(evs) == []


if __name__ == "__main__":
    name = sys.argv[1]
    globals()[f"scenario_{name}"]()
    print(f"scenario {name}: OK")
