"""Fault-injection + self-healing tests (core/faults.py, DESIGN.md §8).

Pins, in order of importance:
  1. the zero-fault identity: an all-off ``FaultConfig`` is bitwise
     invisible — identical trajectories to ``faults=None`` on both the
     dense and Pallas sweep backends;
  2. the Markov availability chain reduces bitwise to the paper's i.i.d.
     Bernoulli sampling at ``p_stay = p`` and matches its stationary
     moments (mean p, lag-1 autocorrelation (p_stay-p)/(1-p)) otherwise;
  3. the defenses actually heal: scrubbing keeps NaN-blowup and bit-flip
     runs finite, the divergence sentinel rolls back and backs off;
  4. resumable sweeps restart bitwise mid-grid from a checkpoint, without
     retracing, and refuse foreign checkpoints;
  5. checkpointer saves are atomic and restores validate up front.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers.prop import given, settings, st

from repro.checkpoint import checkpointer
from repro.core import artemis as art
from repro.core import dist
from repro.core import faults
from repro.core import federated as fed
from repro.core import sweep as sw
from repro.kernels import ops

KEY = jax.random.PRNGKey(42)
N, D = 8, 16
BACKENDS = ["dense", "pallas"]


@pytest.fixture(scope="module")
def prob():
    p, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=50, d=D, noise=0.3)
    return p


def _cfg(fc=None, variant="artemis", p=0.7, s=1):
    cfg = art.variant_config(variant, D, N, s=s, p=p)
    return dataclasses.replace(cfg, faults=fc)


def _run(prob, cfg, iters=40, backend=None, **kw):
    return sw.run_sweep(prob, [cfg], [0.02], [0], iters=iters, batch=4,
                        backend=backend, **kw)


# ---------------------------------------------------------------------------
# 1. zero-fault identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_zero_fault_config_is_bitwise_identity(prob, backend):
    """FaultConfig() must not move a single bit vs faults=None: every fault
    branch is statically gated, and fault PRNG streams are salted side
    streams that are never drawn when rates are zero."""
    base = _run(prob, _cfg(None), backend=backend)
    zero = _run(prob, _cfg(faults.FaultConfig()), backend=backend)
    assert np.array_equal(base.losses, zero.losses)
    assert np.array_equal(base.bits, zero.bits)
    assert np.array_equal(base.dists, zero.dists)
    assert np.array_equal(base.w_final, zero.w_final)
    assert np.all(zero.rollbacks == 0) and np.all(zero.gamma_scale == 1.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_markov_p_stay_equals_p_is_bitwise_iid(prob, backend):
    """p_stay = p makes both Markov transition rows equal p, and the chain
    consumes the SAME uniform draw as the i.i.d. mask — bit-for-bit."""
    base = _run(prob, _cfg(None), backend=backend)
    mkv = _run(prob, _cfg(faults.FaultConfig(p_stay=0.7)), backend=backend)
    assert np.array_equal(base.losses, mkv.losses)
    assert np.array_equal(base.bits, mkv.bits)
    assert np.array_equal(base.w_final, mkv.w_final)


# ---------------------------------------------------------------------------
# 2. Markov availability moments
# ---------------------------------------------------------------------------

def _simulate_chain(fc, p, rounds, workers, seed=7):
    u = jax.random.uniform(jax.random.PRNGKey(seed), (rounds, workers))

    def step(prev, inp):
        k, uk = inp
        part = faults.participation(fc, p, uk, prev, k)
        return part, part

    _, series = jax.lax.scan(step, jnp.zeros((workers,)),
                             (jnp.arange(rounds), u))
    return np.asarray(series)


@given(st.floats(0.55, 0.95))
@settings(max_examples=5, deadline=None)
def test_markov_stationary_moments(p_stay):
    """Seeded moment check: stationary mean == p and lag-1 autocorrelation
    == (p_stay - p)/(1 - p), the closed form markov_autocorr() reports."""
    p = 0.5
    fc = faults.FaultConfig(p_stay=p_stay)
    x = _simulate_chain(fc, p, rounds=2000, workers=64)
    x = x[100:]                                   # burn-in to stationarity
    assert abs(x.mean() - p) < 0.02
    a, b = np.ravel(x[1:]), np.ravel(x[:-1])
    rho = np.corrcoef(a, b)[0, 1]
    want = faults.markov_autocorr(fc, p)
    assert want == pytest.approx((p_stay - p) / (1.0 - p))
    assert abs(rho - want) < 0.05


def test_markov_infeasible_chain_raises(prob):
    """p close to 1 with a sticky-off chain needs P(0->1) > 1: reject at
    config-build time, not with silent clamping inside the trace."""
    fc = faults.FaultConfig(p_stay=0.1)
    with pytest.raises(ValueError, match="infeasible"):
        faults.markov_rates(fc, 0.9)
    with pytest.raises(ValueError, match="infeasible"):
        _run(prob, _cfg(fc, p=0.9))


def test_fault_config_validation():
    with pytest.raises(ValueError):
        faults.FaultConfig(bitflip_rate=1.5)
    with pytest.raises(ValueError):
        faults.FaultConfig(p_stay=-0.1)
    with pytest.raises(ValueError):
        faults.FaultConfig(backoff=0.0)
    assert not faults.FaultConfig().enabled
    assert faults.FaultConfig(scrub=True).enabled


# ---------------------------------------------------------------------------
# 3. defenses heal injected faults
# ---------------------------------------------------------------------------

def test_straggler_drops_meter_fewer_bits(prob):
    """Stragglers never upload, so the metered uplink bits shrink while the
    run stays finite (they are just extra non-participants to PP2)."""
    base = _run(prob, _cfg(None))
    slow = _run(prob, _cfg(faults.FaultConfig(straggler_rate=0.5)))
    assert np.all(np.isfinite(slow.losses))
    assert slow.bits[0, 0, 0, -1] < base.bits[0, 0, 0, -1]


@pytest.mark.parametrize("backend", BACKENDS)
def test_nan_blowup_scrub_recovers(prob, backend):
    """NaN gradient blowups poison the unprotected dense run; with scrubbing
    the blown-up worker is masked inactive (PP2 zero-scale) and the sweep
    still converges.  (The Pallas wire survives even unprotected: its
    encode kernel clamps all-NaN tiles to scale 0, so the poisoned payload
    already decodes to zero — pinned separately below.)"""
    fc_bad = faults.FaultConfig(blowup_rate=0.25)
    bad = _run(prob, _cfg(fc_bad), backend=backend)
    if backend == "dense":
        assert not np.isfinite(bad.losses[0, 0, 0, -1])
    else:
        assert np.all(np.isfinite(bad.losses))

    fc_ok = faults.FaultConfig(blowup_rate=0.25, scrub=True)
    ok = _run(prob, _cfg(fc_ok), backend=backend)
    assert np.all(np.isfinite(ok.losses))
    assert ok.losses[0, 0, 0, -1] < ok.losses[0, 0, 0, 0]


@pytest.mark.parametrize("backend", BACKENDS)
def test_bitflip_scrub_sentinel_keeps_run_finite(prob, backend):
    """Wire bit-flips produce NaN/Inf scales (scrubbed as corrupt payloads)
    and occasionally huge-but-finite ones (caught by the divergence
    sentinel); together the run stays finite and converging."""
    fc = faults.FaultConfig(bitflip_rate=0.05, scrub=True, sentinel=1e4,
                            backoff=0.5)
    res = _run(prob, _cfg(fc), backend=backend)
    assert np.all(np.isfinite(res.losses))
    assert res.losses[0, 0, 0, -1] < res.losses[0, 0, 0, 0]


def test_sentinel_rolls_back_and_backs_off(prob):
    """Large finite blowups sail past the finite-scrubber by design; the
    sentinel catches them at the next eval, restores the last good carry,
    and shrinks gamma geometrically.  (1e15, not 1e30: a value whose square
    overflows f32 turns the payload non-finite and the scrubber would
    swallow it before the sentinel ever sees a bad loss.)"""
    fc = faults.FaultConfig(blowup_rate=0.1, blowup_value=1e15, scrub=True,
                            sentinel=1e3, backoff=0.5)
    res = _run(prob, _cfg(fc))
    assert np.all(np.isfinite(res.losses))
    rb = int(res.rollbacks[0, 0, 0])
    assert rb >= 1
    gs = float(res.gamma_scale[0, 0, 0])
    assert gs <= 0.5 ** 1 and gs == pytest.approx(0.5 ** rb)


def test_wire_scrubbed_stat_reported():
    """artemis_round reports how many payloads the server dropped."""
    cfg = _cfg(faults.FaultConfig(scrub=True), p=1.0)
    st0 = art.init_state(cfg)
    g = jax.random.normal(KEY, (N, D))
    g = g.at[2].set(jnp.nan)                       # one poisoned worker
    omega, _, stats = art.artemis_round(cfg, st0, g, KEY,
                                        jnp.ones((N,)), backend="dense")
    assert np.all(np.isfinite(np.asarray(omega)))
    assert float(stats["wire_scrubbed"]) == 1.0


# ---------------------------------------------------------------------------
# 4. resumable sweeps
# ---------------------------------------------------------------------------

def test_checkpointed_sweep_is_bitwise_plain(prob, tmp_path):
    """Segmented execution (same scan body, checkpoint barriers between
    segments) returns the bit-identical result of the whole-run program."""
    plain = _run(prob, _cfg(None), iters=40, eval_every=2)
    ck = _run(prob, _cfg(None), iters=40, eval_every=2,
              checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=10)
    for f in ("losses", "bits", "dists", "w_final", "w_avg", "w_tail_avg"):
        assert np.array_equal(getattr(plain, f), getattr(ck, f)), f


def test_resume_mid_grid_is_bitwise(prob, tmp_path):
    """Kill-and-restart: rewind LATEST to an early snapshot and resume; the
    completed result is bitwise the uninterrupted run, with zero retraces
    (the segment program is already in the compile cache)."""
    ckdir = str(tmp_path / "ck")
    full = _run(prob, _cfg(None), iters=40, eval_every=2,
                checkpoint_dir=ckdir, checkpoint_every=10)
    # simulate a crash after the first segment: LATEST points at snapshot 5
    # (5 evals = 10 rounds done); the later step dirs just become garbage
    with open(os.path.join(ckdir, "LATEST"), "w") as f:
        f.write("5")
    res = _run(prob, _cfg(None), iters=40, eval_every=2,
               checkpoint_dir=ckdir, checkpoint_every=10, resume=True)
    assert res.traces == 0
    for f_ in ("losses", "bits", "dists", "w_final"):
        assert np.array_equal(getattr(full, f_), getattr(res, f_)), f_


def test_resume_refuses_foreign_checkpoint(prob, tmp_path):
    """A checkpoint from a different sweep (here: different gamma) must be
    rejected by fingerprint, not silently restored into wrong cells."""
    ckdir = str(tmp_path / "ck")
    sw.run_sweep(prob, [_cfg(None)], [0.02], [0], iters=40, batch=4,
                 eval_every=2, checkpoint_dir=ckdir, checkpoint_every=20)
    with pytest.raises(ValueError, match="different sweep"):
        sw.run_sweep(prob, [_cfg(None)], [0.05], [0], iters=40, batch=4,
                     eval_every=2, checkpoint_dir=ckdir, checkpoint_every=20,
                     resume=True)


def test_checkpoint_arg_validation(prob, tmp_path):
    cfg = _cfg(None)
    with pytest.raises(ValueError, match="requires checkpoint_dir"):
        _run(prob, cfg, resume=True)
    with pytest.raises(ValueError, match="requires checkpoint_dir"):
        _run(prob, cfg, checkpoint_every=10)
    with pytest.raises(ValueError, match="group_by_variant"):
        _run(prob, cfg, checkpoint_dir=str(tmp_path), group_by_variant=True)
    with pytest.raises(ValueError, match="multiple"):
        _run(prob, cfg, iters=40, eval_every=2, checkpoint_every=3,
             checkpoint_dir=str(tmp_path))


# ---------------------------------------------------------------------------
# 5. checkpointer: atomic saves, validating restores
# ---------------------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(6, dtype=jnp.float32),
            "step": jnp.zeros((), jnp.int32)}


def test_save_leaves_no_temp_files(tmp_path):
    d = checkpointer.save(str(tmp_path), 3, _tree())
    names = []
    for root, _, files in os.walk(tmp_path):
        names += files
    assert not [n for n in names if ".tmp." in n], names
    assert os.path.exists(os.path.join(d, "arrays.npz"))
    assert checkpointer.latest_step(str(tmp_path)) == 3


def test_restore_validates_keys_shapes_dtypes(tmp_path):
    checkpointer.save(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError, match="missing keys"):
        checkpointer.restore(str(tmp_path), {**_tree(), "extra": jnp.ones(2)})
    with pytest.raises(ValueError, match="unexpected keys"):
        checkpointer.restore(str(tmp_path), {"w": jnp.zeros(6)})
    with pytest.raises(ValueError, match="shape"):
        checkpointer.restore(
            str(tmp_path), {"w": jnp.zeros(7), "step": jnp.zeros((), jnp.int32)})
    with pytest.raises(ValueError, match="dtype"):
        checkpointer.restore(
            str(tmp_path), {"w": jnp.zeros(6, jnp.int32),
                            "step": jnp.zeros((), jnp.int32)})


def test_read_manifest_round_trips_extra(tmp_path):
    checkpointer.save(str(tmp_path), 2, _tree(), extra={"fingerprint": "abc"})
    man = checkpointer.read_manifest(str(tmp_path))
    assert man["extra"]["fingerprint"] == "abc"
    with pytest.raises(FileNotFoundError):
        checkpointer.read_manifest(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# 6. NaN-scale clamp regression (kernels + dist wire)
# ---------------------------------------------------------------------------

def test_nan_tile_decodes_to_finite_zero_kernels():
    """An all-NaN tile must ship a zero scale (not NaN) so dequantize is
    exactly 0 whatever the int8 levels hold — through the Pallas kernels."""
    x = jnp.full((64,), jnp.nan)
    out = ops.compress(KEY, x, s=1)
    assert np.array_equal(np.asarray(out), np.zeros((64,), np.float32))


def test_nan_row_decodes_to_finite_zero_dist():
    x = jnp.full((4, 8), jnp.nan)
    q, scale = dist.squant_encode(KEY, x, 1)
    assert np.all(np.asarray(scale) == 0.0)
    out = dist.squant_decode(q, scale)
    assert np.array_equal(np.asarray(out), np.zeros((4, 8), np.float32))


def test_nan_tree_compress_stays_finite():
    tree = {"a": jnp.full((3, 5), jnp.nan), "b": jnp.ones((4,))}
    out = ops.tree_compress(KEY, tree, s=1)
    assert np.all(np.isfinite(np.asarray(out["a"])))
    assert np.all(np.isfinite(np.asarray(out["b"])))
