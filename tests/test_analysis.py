"""repro.analysis tests: every lint rule fires on its seeded fixture and
stays silent on the clean twin; suppression (pragma + baseline) works;
JSON/SARIF serialize; the trace auditor flags a deliberately retracing
callable and stays silent on shape-stable ones; the repo itself lints
clean (the CI gate's precondition)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import astlint, findings as F, hlo_checks, trace_audit

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
FIX = os.path.join(HERE, "fixtures", "analysis")


def _rules(findings, suppressed=False):
    return {f.rule for f in findings if f.suppressed == suppressed}


def _lint_fixture(kind, name):
    return astlint.lint_file(os.path.join(FIX, kind, name))


# ---------------------------------------------------------------------------
# astlint: bad fixture fires / clean twin silent
# ---------------------------------------------------------------------------

BAD_CASES = [
    ("prng_bad.py", {"prng-key-reuse", "prng-split-overflow"}),
    ("tracer_bad.py", {"tracer-python-branch"}),
    ("jit_global_bad.py", {"jit-mutable-global"}),
    ("interpret_bad.py", {"hardcoded-interpret"}),
    ("static_bad.py", {"static-unhashable-default"}),
    ("print_bad.py", {"print-in-library"}),
]

CLEAN_TWINS = ["prng_clean.py", "tracer_clean.py", "jit_global_clean.py",
               "interpret_clean.py", "static_clean.py", "print_clean.py"]


@pytest.mark.parametrize("name,expected", BAD_CASES)
def test_rule_fires_on_bad_fixture(name, expected):
    got = _rules(_lint_fixture("bad", name))
    assert expected <= got, (name, got)


def test_prng_bad_counts():
    fs = _lint_fixture("bad", "prng_bad.py")
    assert sum(f.rule == "prng-key-reuse" for f in fs) == 2
    assert sum(f.rule == "prng-split-overflow" for f in fs) == 1


@pytest.mark.parametrize("name", CLEAN_TWINS)
def test_clean_twin_is_silent(name):
    fs = _lint_fixture("clean", name)
    assert fs == [], [f.format() for f in fs]


def test_repo_lints_clean():
    """The CI gate's precondition: src/ has zero ACTIVE findings (the
    documented pragmas stay suppressed, nothing else fires)."""
    fs = astlint.lint_paths([os.path.join(ROOT, "src")], rel_to=ROOT)
    act = F.active(fs)
    assert act == [], [f.format() for f in act]


# ---------------------------------------------------------------------------
# suppression: pragma + baseline; serialization
# ---------------------------------------------------------------------------

def test_inline_pragma_suppresses():
    src = ("import jax\n"
           "def f(key):\n"
           "    a = jax.random.normal(key, (2,))\n"
           "    b = jax.random.normal(key, (2,))"
           "  # repro-lint: allow=prng-key-reuse\n"
           "    return a + b\n")
    fs = astlint.lint_source("x.py", src)
    assert len(fs) == 1 and fs[0].suppressed and fs[0].suppressed_by == "pragma"
    assert F.active(fs) == []


def test_def_line_pragma_covers_function():
    src = ("import jax\n"
           "def f(key):  # repro-lint: allow=prng-key-reuse\n"
           "    a = jax.random.normal(key, (2,))\n"
           "    b = jax.random.normal(key, (2,))\n"
           "    return a + b\n")
    fs = astlint.lint_source("x.py", src)
    assert [f.suppressed for f in fs] == [True]


def test_baseline_suppression_and_precedence():
    fs = [F.Finding("prng-key-reuse", "error", "a.py", 3, "m"),
          F.Finding("prng-key-reuse", "error", "b.py", 9, "m"),
          F.Finding("tracer-python-branch", "warning", "a.py", 5, "m")]
    F.apply_baseline(fs, [{"rule": "prng-key-reuse", "path": "a.py"}])
    assert [f.suppressed for f in fs] == [True, False, False]
    assert {f.rule for f in F.active(fs)} == {"prng-key-reuse",
                                              "tracer-python-branch"}


def test_json_and_sarif_shapes():
    fs = [F.Finding("prng-key-reuse", "error", "a.py", 3, "boom",
                    suppressed=True, suppressed_by="baseline"),
          F.Finding("trace-retrace", "error", "sweep_grid", 0, "retraced")]
    payload = json.loads(F.to_json(fs))
    assert payload["counts"] == {"total": 2, "active": 1, "suppressed": 1}
    sarif = json.loads(F.to_sarif(fs))
    run = sarif["runs"][0]
    assert {r["id"] for r in run["tool"]["driver"]["rules"]} == {
        "prng-key-reuse", "trace-retrace"}
    res = run["results"]
    assert res[0]["suppressions"][0]["kind"] == "external"
    assert res[0]["locations"][0]["physicalLocation"]["region"][
        "startLine"] == 3
    assert "suppressions" not in res[1]


def test_hygiene_rule_clean_on_repo():
    assert astlint.hygiene_findings(ROOT) == []


# ---------------------------------------------------------------------------
# trace audit: compile-log capture + retrace regression
# ---------------------------------------------------------------------------

def test_compile_log_captures_jit_name():
    def freshly_named_fn_tc1(x):
        return x * 3 + 1

    fn = jax.jit(freshly_named_fn_tc1)
    with trace_audit.compile_log() as names:
        jax.block_until_ready(fn(jnp.arange(7.0)))
    assert trace_audit.compile_counts(names).get("freshly_named_fn_tc1") == 1


def test_auditor_flags_deliberate_retrace():
    """Perturbing an argument SHAPE across calls forces a retrace per call;
    the auditor must flag it."""
    def leaky_fn_tc2(x):
        return (x * 2).sum()

    fn = jax.jit(leaky_fn_tc2)
    calls = [(jnp.arange(4.0),), (jnp.arange(5.0),), (jnp.arange(6.0),)]
    fs = trace_audit.audit_no_retrace(fn, calls, "leaky_fn_tc2",
                                      entry="retrace_fixture")
    assert [f.rule for f in fs] == ["trace-retrace"]
    assert "3x" in fs[0].message


def test_auditor_silent_on_shape_stable_calls():
    def stable_fn_tc3(x):
        return (x + 1.0).sum()

    fn = jax.jit(stable_fn_tc3)
    calls = [(jnp.full((4,), float(i)),) for i in range(3)]
    assert trace_audit.audit_no_retrace(fn, calls, "stable_fn_tc3") == []


@pytest.mark.slow
def test_sweep_grid_entry_point_single_compile():
    """Acceptance: the registered sweep entry point proves one compile
    across a 2x2x2 grid (fresh executable-cache key per test run is
    guaranteed by the distinctive problem shape)."""
    assert trace_audit._audit_sweep_grid() == []


# ---------------------------------------------------------------------------
# hlo checks: text-level units + the sweep donation audit
# ---------------------------------------------------------------------------

def test_count_output_aliases():
    txt = ('func @main(%a: tensor<4xf32> {tf.aliasing_output = 0 : i32},\n'
           '           %b: tensor<4xf32> {tf.aliasing_output = 1 : i32})')
    assert hlo_checks.count_output_aliases(txt) == 2
    assert hlo_checks.count_output_aliases("no aliases here") == 0


def test_host_transfer_findings():
    dirty = "%i = f32[4] infeed(token[] %tok)"
    fs = hlo_checks.host_transfer_findings(dirty, "e")
    assert [f.rule for f in fs] == ["hlo-host-transfer"]
    assert hlo_checks.host_transfer_findings(
        "%cp = s8[12] collective-permute(s8[12] %q)", "e") == []


def test_wire_findings_flag_decompressed_payload():
    declared = {"s8": 960.0, "f32": 60.0}     # squant-like split
    # healthy wire: s8 dominates, f32 = scales
    clean = {("collective-permute", "s8"): 2880,
             ("collective-permute", "f32"): 180,
             ("all-reduce", "f32"): 12}
    assert hlo_checks.wire_findings(clean, declared, "e",
                                    payload_f32_bytes=4096.0) == []
    # decompressed: payload went out as f32
    bad = {("collective-permute", "f32"): 4096,
           ("all-reduce", "f32"): 12}
    rules = {f.rule for f in hlo_checks.wire_findings(
        bad, declared, "e", payload_f32_bytes=4096.0)}
    assert "hlo-uncompressed-wire" in rules
    # dense psum bypassing the ring
    psum = {("collective-permute", "s8"): 2880,
            ("collective-permute", "f32"): 180,
            ("all-reduce", "f32"): 8192}
    rules = {f.rule for f in hlo_checks.wire_findings(
        psum, declared, "e", payload_f32_bytes=4096.0)}
    assert rules == {"hlo-f32-allreduce-payload"}


@pytest.mark.slow
def test_sweep_donation_audit_clean():
    """lower_sweep's StableHLO aliases every donated grid-carry buffer."""
    assert hlo_checks.audit_sweep() == []


# ---------------------------------------------------------------------------
# CLI: exits non-zero on the seeded fixtures, zero on clean paths
# ---------------------------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "repro.analysis", *args],
                          capture_output=True, text=True, cwd=ROOT, env=env)


def test_cli_fails_on_seeded_fixtures():
    res = _run_cli("--paths", os.path.join(FIX, "bad"))
    assert res.returncode == 1, res.stdout + res.stderr
    for rule in ("prng-key-reuse", "prng-split-overflow",
                 "tracer-python-branch", "jit-mutable-global",
                 "hardcoded-interpret", "static-unhashable-default",
                 "print-in-library"):
        assert rule in res.stdout, rule


def test_cli_clean_on_clean_twins():
    res = _run_cli("--paths", os.path.join(FIX, "clean"))
    assert res.returncode == 0, res.stdout + res.stderr


def test_cli_emits_json_and_sarif(tmp_path):
    jpath, spath = str(tmp_path / "f.json"), str(tmp_path / "f.sarif")
    res = _run_cli("--paths", os.path.join(FIX, "bad", "static_bad.py"),
                   "--json", jpath, "--sarif", spath, "-q")
    assert res.returncode == 1
    payload = json.load(open(jpath))
    assert payload["counts"]["active"] >= 1
    sarif = json.load(open(spath))
    assert sarif["version"] == "2.1.0"
    assert sarif["runs"][0]["results"]
