"""Make ``tests/helpers`` importable as the ``helpers`` package and share
expensive fixtures across test modules."""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture(scope="session")
def lsr_noiseless_session():
    from repro.core import federated as fed

    prob, _ = fed.make_lsr_problem(jax.random.PRNGKey(42), n_workers=10,
                                   n_per=100, d=20, noise=0.0)
    return prob


@pytest.fixture(scope="session")
def lsr_noisy_session():
    from repro.core import federated as fed

    prob, _ = fed.make_lsr_problem(jax.random.PRNGKey(42), n_workers=10,
                                   n_per=100, d=20, noise=0.4)
    return prob


@pytest.fixture(scope="session")
def logistic_session():
    from repro.core import federated as fed

    return fed.make_logistic_problem(jax.random.PRNGKey(3), n_workers=10,
                                     n_per=200, d=2)
