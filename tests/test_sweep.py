"""Sweep-engine tests: grid == per-cell, monitoring stride, single-trace
compilation, Pallas backend agreement, and the unified bit-metering rule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import artemis as art
from repro.core import compression as comp
from repro.core import federated as fed
from repro.core import sweep as sw

KEY = jax.random.PRNGKey(42)
N, D = 8, 16
VARIANTS = ["sgd", "qsgd", "artemis"]
GAMMAS = [0.01, 0.02]
SEEDS = [0, 1]


@pytest.fixture(scope="module")
def prob():
    p, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=50, d=D, noise=0.3)
    return p


@pytest.fixture(scope="module")
def grid(prob):
    cfgs = [art.variant_config(v, D, N, p=0.7) for v in VARIANTS]
    res = sw.run_sweep(prob, cfgs, GAMMAS, SEEDS, iters=60, batch=4,
                       eval_every=1)
    return cfgs, res


def test_grid_matches_per_cell_run(prob, grid):
    """Every grid cell reproduces a per-cell ``run`` with the same seed.

    Equality is up to float32 reassociation: the grid program batches the
    per-cell matmuls (vmap width V*G*S vs 1), which reorders reductions by
    ~1 ulp/step.  A semantic divergence would show up at 1e-2+.
    """
    cfgs, res = grid
    for vi in range(len(VARIANTS)):
        for gi, g in enumerate(GAMMAS):
            for si, s in enumerate(SEEDS):
                r = fed.run(prob, cfgs[vi], gamma=g, iters=60,
                            key=jax.random.PRNGKey(s), batch=4)
                np.testing.assert_allclose(res.losses[vi, gi, si], r.losses,
                                           rtol=1e-4, atol=1e-6)
                np.testing.assert_allclose(res.bits[vi, gi, si], r.bits,
                                           rtol=1e-5)


def test_run_is_bitwise_one_cell_sweep(prob):
    """``run`` IS the engine: a 1-cell sweep returns bit-identical series."""
    cfg = art.variant_config("artemis", D, N, p=0.7)
    r = fed.run(prob, cfg, gamma=0.02, iters=40, key=KEY, batch=4)
    res = sw.run_sweep(prob, [cfg], [0.02], jnp.asarray(KEY)[None], iters=40,
                       batch=4)
    assert np.array_equal(res.losses[0, 0, 0], r.losses)
    assert np.array_equal(res.bits[0, 0, 0], r.bits)


def test_matches_legacy_percell_loop(prob):
    """Cross-check losses AND metered bits against the seed's unbatched scan
    (run_percell), with partial participation engaged."""
    cfg = art.variant_config("qsgd", D, N, p=0.4)
    r_old = fed.run_percell(prob, cfg, gamma=0.02, iters=50, key=KEY, batch=4)
    r_new = fed.run(prob, cfg, gamma=0.02, iters=50, key=KEY, batch=4)
    np.testing.assert_allclose(r_new.losses, r_old.losses, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(r_new.bits, r_old.bits, rtol=1e-5)


def test_eval_every_is_a_stride(prob, grid):
    """Thinned monitoring returns exactly every k-th point of the dense run."""
    cfgs, res1 = grid
    res5 = sw.run_sweep(prob, cfgs, GAMMAS, SEEDS, iters=60, batch=4,
                        eval_every=5)
    assert res5.losses.shape[-1] == 12
    np.testing.assert_allclose(res5.losses, res1.losses[..., 4::5], rtol=1e-6)
    np.testing.assert_allclose(res5.bits, res1.bits[..., 4::5], rtol=1e-6)
    np.testing.assert_array_equal(res5.eval_iters, np.arange(4, 60, 5))


def test_whole_grid_compiles_once():
    """One trace for a fresh grid; zero for new gammas/seeds on the same grid."""
    p, _ = fed.make_lsr_problem(jax.random.PRNGKey(7), n_workers=4, n_per=30,
                                d=8, noise=0.1)
    cfgs = [art.variant_config(v, 8, 4) for v in ["sgd", "qsgd", "artemis",
                                                  "biqsgd", "diana", "dore"]]
    res = sw.run_sweep(p, cfgs, [0.01, 0.02, 0.04], [0, 1], iters=20, batch=2)
    assert res.traces == 1, res.traces
    res2 = sw.run_sweep(p, cfgs, [0.005, 0.03, 0.1], [2, 3], iters=20, batch=2)
    assert res2.traces == 0, res2.traces


def test_group_by_variant_matches_batched(prob, grid):
    """group_by_variant=True partitions the grid into V single-variant
    sub-sweeps; results match the vmap-of-switch program up to f32
    batched-reduction reassociation (narrower vmap width reorders sums)."""
    cfgs, res = grid
    resg = sw.run_sweep(prob, cfgs, GAMMAS, SEEDS, iters=60, batch=4,
                        eval_every=1, group_by_variant=True)
    assert resg.losses.shape == res.losses.shape
    np.testing.assert_allclose(resg.losses, res.losses, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(resg.bits, res.bits, rtol=1e-5)
    np.testing.assert_allclose(resg.w_final, res.w_final, rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_array_equal(resg.eval_iters, res.eval_iters)


def test_group_by_variant_trace_count():
    """V traces cold, zero on repeat with fresh gammas/seeds (the sub-sweeps
    share the executable cache)."""
    p, _ = fed.make_lsr_problem(jax.random.PRNGKey(9), n_workers=4, n_per=30,
                                d=8, noise=0.1)
    cfgs = [art.variant_config(v, 8, 4) for v in ["sgd", "qsgd", "artemis"]]
    res = sw.run_sweep(p, cfgs, [0.01, 0.02], [0, 1], iters=20, batch=2,
                       group_by_variant=True)
    assert res.traces == len(cfgs), res.traces
    res2 = sw.run_sweep(p, cfgs, [0.005, 0.03], [2, 3], iters=20, batch=2,
                        group_by_variant=True)
    assert res2.traces == 0, res2.traces


def test_invalid_grid_args(prob):
    cfg_bad = art.variant_config("sgd", D + 1, N)
    with pytest.raises(ValueError):
        sw.run_sweep(prob, [cfg_bad], [0.01], [0], iters=10)
    cfg = art.variant_config("sgd", D, N)
    with pytest.raises(ValueError):
        sw.run_sweep(prob, [cfg], [0.01], [0], iters=10, eval_every=3)


# ---------------------------------------------------------------------------
# backend="pallas"
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant,s", [("qsgd", 1), ("artemis", 1),
                                       ("artemis", 4), ("biqsgd", 2)])
def test_pallas_round_matches_dense(variant, s):
    """Fused-kernel round == dense round within 1e-5 for squant configs."""
    cfg = art.variant_config(variant, D, N, s=s, p=0.6)
    g = jax.random.normal(KEY, (N, D))
    st = art.init_state(cfg)._replace(
        h=0.3 * jax.random.normal(jax.random.PRNGKey(1), (N, D)))
    act = (jax.random.uniform(jax.random.PRNGKey(2), (N,)) < 0.6
           ).astype(jnp.float32)
    o_d, st_d, stats_d = art.artemis_round(cfg, st, g, KEY, act,
                                           backend="dense")
    o_p, st_p, stats_p = art.artemis_round(cfg, st, g, KEY, act,
                                           backend="pallas")
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_d), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_p.h), np.asarray(st_d.h),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_p.hbar), np.asarray(st_d.hbar),
                               atol=1e-5)
    np.testing.assert_allclose(float(stats_p["compress_err_up"]),
                               float(stats_d["compress_err_up"]), rtol=1e-4,
                               atol=1e-6)


def test_pallas_round_pp1(variant="artemis"):
    cfg = art.variant_config(variant, D, N, s=2, p=0.5, pp_mode="pp1")
    g = jax.random.normal(KEY, (N, D))
    st = art.init_state(cfg)._replace(
        h=0.2 * jax.random.normal(jax.random.PRNGKey(3), (N, D)))
    act = (jax.random.uniform(jax.random.PRNGKey(4), (N,)) < 0.5
           ).astype(jnp.float32)
    o_d, _, _ = art.artemis_round(cfg, st, g, KEY, act, backend="dense")
    o_p, _, _ = art.artemis_round(cfg, st, g, KEY, act, backend="pallas")
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_d), atol=1e-5)


def test_pallas_backend_falls_back_and_supports_ef():
    """Codec dispatch replaced the old hard-fail table: non-fusable codecs
    on backend='pallas' take the dense uplink BITWISE, and error feedback
    now runs through the fused kernel (Dore-on-Pallas)."""
    g = jax.random.normal(KEY, (N, D))
    act = jnp.ones((N, 1))
    # identity uplink (sgd): no fused kernel family -> dense path, bitwise
    cfg = art.variant_config("sgd", D, N)
    o_d, st_d, _ = art.artemis_round(cfg, art.init_state(cfg), g, KEY, act,
                                     backend="dense")
    o_p, st_p, _ = art.artemis_round(cfg, art.init_state(cfg), g, KEY, act,
                                     backend="pallas")
    np.testing.assert_array_equal(np.asarray(o_p), np.asarray(o_d))
    np.testing.assert_array_equal(np.asarray(st_p.h), np.asarray(st_d.h))
    # error feedback on the fused squant uplink matches dense to kernel tol
    cfg_ef = art.variant_config("dore", D, N, s=2, p=0.6)
    st0 = art.init_state(cfg_ef)._replace(
        e=0.1 * jax.random.normal(jax.random.PRNGKey(5), (N, D)),
        h=0.3 * jax.random.normal(jax.random.PRNGKey(6), (N, D)))
    a = (jax.random.uniform(jax.random.PRNGKey(7), (N,)) < 0.6
         ).astype(jnp.float32)
    o_d, st_d, _ = art.artemis_round(cfg_ef, st0, g, KEY, a, backend="dense")
    o_p, st_p, _ = art.artemis_round(cfg_ef, st0, g, KEY, a, backend="pallas")
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_d), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_p.e), np.asarray(st_d.e),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_p.h), np.asarray(st_d.h),
                               atol=1e-5)


def test_unknown_backend_rejected():
    cfg = art.variant_config("artemis", D, N)
    with pytest.raises(ValueError):
        art.artemis_round(cfg, art.init_state(cfg), jnp.ones((N, D)), KEY,
                          backend="mystery")


def test_pallas_sweep_dore(prob):
    """Dore (EF) now runs end-to-end on the pallas sweep backend."""
    cfgs = [art.variant_config("dore", D, N, s=3, p=0.7)]
    r_p = sw.run_sweep(prob, cfgs, [0.02], [0], iters=15, batch=4,
                       backend="pallas")
    r_d = sw.run_sweep(prob, cfgs, [0.02], [0], iters=15, batch=4,
                       backend="dense")
    assert np.all(np.isfinite(r_p.losses))
    np.testing.assert_allclose(r_p.losses, r_d.losses, rtol=1e-4, atol=1e-6)


def test_pallas_sweep(prob):
    """The engine accepts backend='pallas' end-to-end (vmapped kernels)."""
    cfgs = [art.variant_config("artemis", D, N, s=1)]
    r_p = sw.run_sweep(prob, cfgs, [0.02], [0], iters=15, batch=4,
                       backend="pallas")
    r_d = sw.run_sweep(prob, cfgs, [0.02], [0], iters=15, batch=4,
                       backend="dense")
    np.testing.assert_allclose(r_p.losses, r_d.losses, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# unified bit metering (Remark 3)
# ---------------------------------------------------------------------------

def test_metering_full_participation(prob):
    """p=1: every worker pays uplink + exactly this round's broadcast, every
    round including the first."""
    cfg = art.variant_config("artemis", D, N, p=1.0)
    c_up, c_dwn = cfg.compressors()
    r = fed.run(prob, cfg, gamma=0.01, iters=20, key=KEY, batch=2)
    per_round = N * (c_up.bits(D) + max(c_dwn.bits(D), 1.0))
    expect = per_round * np.arange(1, 21)
    np.testing.assert_allclose(r.bits, expect, rtol=1e-5)


def test_metering_catchup_cap(prob):
    """p<1: a returning worker pays missed * M2, capped at M1 = 32d once it
    has been away more than floor(M1/M2) rounds (Remark 3)."""
    cfg = art.variant_config("artemis", D, N, p=0.15)
    c_up, c_dwn = cfg.compressors()
    m1 = comp.FP_BITS * D
    r = fed.run(prob, cfg, gamma=0.01, iters=120, key=KEY, batch=2)
    per_round = np.diff(np.concatenate([[0.0], r.bits]))
    cap = N * (c_up.bits(D) + m1)
    assert (per_round <= cap + 1e-4).all()
    # rare participation must trigger the full-model cap at least once:
    # with p=0.15 the typical gap >> floor(M1/M2) for s=1 quantization
    window = max(int(m1 // max(c_dwn.bits(D), 1.0)), 1)
    gaps_over = per_round > c_up.bits(D)  # any active round
    assert gaps_over.any()
