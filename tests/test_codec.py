"""Conformance suite for the unified wire-codec layer (core/codec.py).

Three contracts, checked for EVERY registered codec:

  1. round-trip: ``decode(encode(key, x))`` restores shape/dtype, and for the
     operators that predate the codec layer (squant / tile_squant / sparsify)
     it is BITWISE identical to the legacy one-shot formulas (inlined here so
     the pin survives the refactor that deleted them);
  2. Assumption 5 (property test via helpers.prop): unbiased codecs satisfy
     ``E[C(x)] ~= x`` and ``E||C(x) - x||^2 <= omega * ||x||^2``;
  3. wire accounting: ``wire_bytes(shape)`` equals the actual payload leaf
     nbytes by HLO dtype, and ``validate`` accepts clean payloads / rejects
     scrambled ones (the server-side scrubbing contract of core/faults.py).
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from helpers.prop import given, settings, st  # noqa: E402

from repro.core import codec as wire  # noqa: E402
from repro.core import compression as comp  # noqa: E402
from repro.core import faults  # noqa: E402

D = 257
KEY = jax.random.PRNGKey(0)
X = jax.random.normal(jax.random.PRNGKey(7), (D,))

CODEC_KWARGS = {
    "identity": {},
    "none": {},
    "squant": {"s": 3},
    "tile_squant": {"s": 2, "tile": 64},
    "row_squant": {"s": 3},
    "sparsify": {"q": 0.3},
    "topk": {"frac": 0.1},
}

_HLO_DTYPE = {"int8": "s8", "int32": "s32", "float32": "f32"}


def _codec(name):
    return wire.make_codec(name, D, **CODEC_KWARGS[name])


# ---------------------------------------------------------------------------
# registry + round-trip conformance
# ---------------------------------------------------------------------------

def test_registry_covers_all_legacy_operators():
    names = wire.available()
    for want in ("identity", "none", "squant", "tile_squant", "row_squant",
                 "sparsify", "topk"):
        assert want in names
    with pytest.raises(ValueError):
        wire.make_codec("mystery", D)


@pytest.mark.parametrize("name", sorted(CODEC_KWARGS))
def test_roundtrip_shape_dtype(name):
    c = _codec(name)
    p = c.encode(KEY, X)
    xh = c.decode(p)
    assert xh.shape == X.shape and xh.dtype == X.dtype
    # 2-D input round-trips too (the mesh hands codecs [rows, row] buckets)
    x2 = jax.random.normal(jax.random.PRNGKey(8), (33, 65))
    xh2 = c.decode(c.encode(jax.random.PRNGKey(4), x2))
    assert xh2.shape == x2.shape
    # __call__ is exactly the round-trip
    np.testing.assert_array_equal(np.asarray(c(KEY, X)), np.asarray(xh))


@pytest.mark.parametrize("name", sorted(CODEC_KWARGS))
def test_compressor_wrapper_matches_codec(name):
    """core/compression.py's Compressor is a thin wrapper: same omega, same
    bits metering, bitwise-identical compress."""
    c = _codec(name)
    cw = comp.make_compressor(name, D, **CODEC_KWARGS[name])
    assert cw.omega == c.omega
    assert cw.bits(D) == c.bits(D)
    np.testing.assert_array_equal(np.asarray(cw(KEY, X)),
                                  np.asarray(c(KEY, X)))


def _legacy_squant(key, x, s):
    # the pre-codec one-shot operator, verbatim: sign * norm * psi / s
    norm = jnp.linalg.norm(x)
    r = jnp.where(norm > 0, jnp.abs(x) / norm * s, jnp.zeros_like(x))
    low = jnp.floor(r)
    u = jax.random.uniform(key, x.shape)
    psi = low + (u < (r - low)).astype(x.dtype)
    return jnp.sign(x) * norm * psi / s


def test_squant_bitwise_vs_legacy():
    for s in (1, 3, 7):
        c = wire.make_codec("squant", D, s=s)
        np.testing.assert_array_equal(
            np.asarray(c(KEY, X)), np.asarray(_legacy_squant(KEY, X, s)))


def test_tile_squant_bitwise_vs_legacy():
    s, tile = 2, 64
    c = wire.make_codec("tile_squant", D, s=s, tile=tile)
    pad = (-D) % tile
    tiles = jnp.pad(X, (0, pad)).reshape(-1, tile)
    norms = jnp.linalg.norm(tiles, axis=1, keepdims=True)
    r = jnp.where(norms > 0, jnp.abs(tiles) / norms * s,
                  jnp.zeros_like(tiles))
    low = jnp.floor(r)
    u = jax.random.uniform(KEY, tiles.shape)
    psi = low + (u < (r - low)).astype(tiles.dtype)
    legacy = (jnp.sign(tiles) * norms * psi / s).reshape(-1)[:D]
    np.testing.assert_array_equal(np.asarray(c(KEY, X)), np.asarray(legacy))


def test_sparsify_bitwise_vs_legacy():
    q = 0.3
    c = wire.make_codec("sparsify", D, q=q)
    mask = jax.random.bernoulli(KEY, q, X.shape)
    legacy = jnp.where(mask, X / q, 0.0)
    np.testing.assert_array_equal(np.asarray(c(KEY, X)), np.asarray(legacy))


def test_topk_exact_k_on_ties():
    """The old sort-threshold + >= kept every tied coordinate (>k coords);
    jax.lax.top_k ships exactly k."""
    x = jnp.concatenate([jnp.full((50,), 2.0), jnp.full((50,), -2.0),
                         0.01 * jnp.arange(100, dtype=jnp.float32)])
    c = wire.make_codec("topk", x.size, frac=0.1)
    k = max(1, int(x.size * 0.1))
    p = c.encode(KEY, x)
    assert p["indices"].shape == (k,)
    xh = c.decode(p)
    assert int(jnp.sum(xh != 0)) == k
    # every kept coordinate is one of the tied max-magnitude entries, exact
    np.testing.assert_array_equal(np.asarray(jnp.abs(xh[xh != 0])),
                                  np.full((k,), 2.0, np.float32))


# ---------------------------------------------------------------------------
# Assumption 5 properties (E[C(x)] ~= x, var <= omega ||x||^2)
# ---------------------------------------------------------------------------

UNBIASED = sorted(n for n in CODEC_KWARGS if _codec(n).unbiased)


@pytest.mark.parametrize("name", UNBIASED)
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_assumption5_unbiased_bounded_variance(name, seed):
    c = _codec(name)
    x = jax.random.normal(jax.random.PRNGKey(seed % 1000), (D,))
    keys = jax.random.split(jax.random.PRNGKey(seed), 512)
    ys = jax.vmap(lambda k: c(k, x))(keys)
    mean = jnp.mean(ys, axis=0)
    nx = float(jnp.linalg.norm(x))
    # E[C(x)] ~= x within Monte-Carlo error of the variance bound
    se = float(jnp.sqrt(c.omega + 1e-12) * nx / np.sqrt(512))
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x),
                               atol=max(5 * se, 1e-6))
    # empirical variance within the Assumption-5 bound (20% MC slack)
    var = float(jnp.mean(jnp.sum(jnp.square(ys - x[None]), axis=-1)))
    assert var <= 1.2 * c.omega * nx**2 + 1e-6, (name, var, c.omega * nx**2)


# ---------------------------------------------------------------------------
# wire accounting + validate/scrub contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CODEC_KWARGS))
def test_wire_bytes_match_payload_nbytes(name):
    c = _codec(name)
    for shape in [(D,), (33, 65)]:
        x = jax.random.normal(jax.random.PRNGKey(9), shape)
        p = c.encode(KEY, x)
        got = {}
        for leaf in jax.tree.leaves(p):
            dt = _HLO_DTYPE[str(leaf.dtype)]
            got[dt] = got.get(dt, 0) + leaf.nbytes
        assert got == c.wire_bytes(shape), (name, shape)
        assert c.wire_bytes_total(shape) == sum(got.values())


@pytest.mark.parametrize("name", sorted(CODEC_KWARGS))
def test_validate_accepts_clean_rejects_nan_scales(name):
    c = _codec(name)
    p = c.encode(KEY, X)
    assert float(c.validate(p)) == 1.0
    # poison every float leaf with NaN: validate must flag the payload
    bad = jax.tree.map(
        lambda l: jnp.full_like(l, jnp.nan)
        if jnp.issubdtype(l.dtype, jnp.floating) else l, p)
    assert float(c.validate(bad)) == 0.0


def test_corrupt_validate_scrub_pipeline():
    """faults.corrupt_payload flips payload bits uniformly across leaf
    dtypes; validate catches out-of-range levels; scrub_payload zeroes the
    flagged payload so decode is exactly 0."""
    c = wire.make_codec("squant", D, s=3)
    p = c.encode(KEY, X)
    crpt = faults.corrupt_payload(jax.random.PRNGKey(11), p, rate=0.5)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(crpt)))
    assert changed, "corrupt_payload at rate=0.5 must flip something"
    # zero rate is the identity, bitwise
    clean = faults.corrupt_payload(jax.random.PRNGKey(11), p, rate=0.0)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(clean)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    valid = c.validate(crpt)
    scrubbed = faults.scrub_payload(crpt, valid)
    if float(valid) == 0.0:
        assert float(jnp.sum(jnp.abs(c.decode(scrubbed)))) == 0.0
    # a forced-invalid payload scrubs to zero regardless
    z = faults.scrub_payload(crpt, jnp.zeros(()))
    assert float(jnp.sum(jnp.abs(c.decode(z)))) == 0.0


def test_mask_payload_zeroes_float_leaves_only():
    c = wire.make_codec("squant", D, s=3)
    p = c.encode(KEY, X)
    off = faults.mask_payload(p, jnp.zeros(()))
    assert float(jnp.sum(jnp.abs(c.decode(off)))) == 0.0
    # int levels ride untouched (the PP2 zero-scale trick keeps wire shape)
    np.testing.assert_array_equal(np.asarray(off["levels"]),
                                  np.asarray(p["levels"]))


def test_payload_is_a_pytree():
    """WirePayload vmaps/jits like any value and flattens sorted-by-key
    (the fault-stream order contract)."""
    c = wire.make_codec("squant", D, s=3)
    xs = jax.random.normal(KEY, (4, D))
    keys = jax.random.split(KEY, 4)
    stacked = jax.vmap(c.encode)(keys, xs)
    assert stacked["levels"].shape == (4, D)
    leaves, treedef = jax.tree.flatten(stacked)
    aux_keys = treedef.children()[0] if False else tuple(sorted(stacked.data))
    assert aux_keys == ("levels", "scales")
    out = jax.jit(jax.vmap(c.decode))(stacked)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jax.vmap(c)(keys, xs)))
