"""Tests for the supporting subsystems: data, optimizers, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.prop import given, settings, st

from repro.checkpoint import checkpointer
from repro.data.synthetic import TokenStream, TokenStreamConfig
from repro.optim import adam, sgd, cosine_lr


# -- data -------------------------------------------------------------------

def test_stream_deterministic():
    cfg = TokenStreamConfig(vocab=128, seq_len=32, batch=4, seed=7)
    a = TokenStream(cfg).batch_at(3)["tokens"]
    b = TokenStream(cfg).batch_at(3)["tokens"]
    assert jnp.array_equal(a, b)
    c = TokenStream(cfg).batch_at(4)["tokens"]
    assert not jnp.array_equal(a, c)


def test_stream_bigram_structure():
    """successor(t) follows t ~bigram_weight of the time."""
    cfg = TokenStreamConfig(vocab=64, seq_len=512, batch=8, bigram_weight=0.7)
    s = TokenStream(cfg)
    toks = np.asarray(s.batch_at(0)["tokens"])
    follows = (s.successor[toks[:, :-1]] == toks[:, 1:]).mean()
    assert 0.6 < follows < 0.85, follows


def test_stream_range():
    cfg = TokenStreamConfig(vocab=50, seq_len=64, batch=2)
    t = np.asarray(TokenStream(cfg).batch_at(0)["tokens"])
    assert t.min() >= 0 and t.max() < 50


# -- optimizers ---------------------------------------------------------------

@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1), lambda: sgd(0.05, 0.9),
                                      lambda: adam(0.1)])
def test_optimizer_quadratic(make_opt):
    opt = make_opt()
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for i in range(200):
        grads = {"w": params["w"] - target}
        upd, state = opt.update(grads, state, jnp.int32(i))
        params = jax.tree.map(lambda p, u: p - u, params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_cosine_schedule():
    sched = cosine_lr(1.0, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == pytest.approx(1.0, abs=0.05)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.1, abs=0.05)


@settings(max_examples=15, deadline=None)
@given(st.floats(0.01, 0.3), st.integers(0, 100))
def test_sgd_property_descent(lr, seed):
    """One SGD step on a convex quadratic never increases the loss."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (5,))
    loss = lambda w_: 0.5 * jnp.sum(w_ ** 2)
    opt = sgd(lr)
    upd, _ = opt.update(jax.grad(loss)(w), opt.init(w), jnp.int32(0))
    assert float(loss(w - upd)) <= float(loss(w)) + 1e-6


# -- checkpointing -------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32), "d": jnp.zeros(())},
            "e": [jnp.full((2,), 7.0)]}
    with tempfile.TemporaryDirectory() as d:
        checkpointer.save(d, 42, tree, extra={"note": "x"})
        assert checkpointer.latest_step(d) == 42
        like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
        out = checkpointer.restore(d, like)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_multiple_steps():
    tree = {"w": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        checkpointer.save(d, 1, tree)
        checkpointer.save(d, 2, jax.tree.map(lambda a: 2 * a, tree))
        out = checkpointer.restore(d, tree)            # latest
        np.testing.assert_array_equal(np.asarray(out["w"]), 2 * np.ones(3))
        out1 = checkpointer.restore(d, tree, step=1)
        np.testing.assert_array_equal(np.asarray(out1["w"]), np.ones(3))


def test_checkpoint_missing():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(FileNotFoundError):
            checkpointer.restore(d, {"w": jnp.ones(1)})


def test_train_state_roundtrip():
    """Full TrainState (params + artemis memory) survives save/restore."""
    from repro import configs
    from repro.core import dist
    from repro.models.model import build_model
    from repro.optim import sgd as mk_sgd
    cfg = configs.get_config("starcoder2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = dist.DistConfig(worker_axes=(), variant="artemis")
    state = dist.TrainState(params, mk_sgd(0.1).init(params),
                            dist.init_dist_state(dcfg, params, 1),
                            jnp.zeros((), jnp.int32))
    with tempfile.TemporaryDirectory() as d:
        checkpointer.save(d, 0, state)
        out = checkpointer.restore(d, state)
        assert jax.tree.structure(out) == jax.tree.structure(state)
