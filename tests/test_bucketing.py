"""Bucketizer layout/roundtrip invariants + bucket_ring kernel oracles.

Mesh-free tests of the bucketed wire's building blocks; the multi-device
ring/transport semantics live in tests/test_bucketed.py (subprocess
scenarios with fake CPU devices).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers.prop import given, settings, st

from repro.core import bucketing as B
from repro.core import dist
from repro.kernels import bucket_ring as BK

KEY = jax.random.PRNGKey(7)


def _tree(key, spec):
    leaves = []
    for i, shape in enumerate(spec):
        key, k = jax.random.split(key)
        leaves.append(jax.random.normal(k, shape))
    return {f"leaf_{i}": l for i, l in enumerate(leaves)}


TREES = [
    [(3, 5), (7,), (2, 2, 2)],
    [(1,), ()],                      # scalar leaf
    [(17, 13)],
    [(256,), (31, 9), (4, 4), (5,)],
]


@pytest.mark.parametrize("spec", TREES)
def test_roundtrip_exact(spec):
    tree = _tree(KEY, spec)
    lay = B.make_layout(tree, bucket_bytes=256, max_buckets=4, row=16)
    buckets = B.bucketize(lay, tree)
    assert buckets.shape == lay.shape
    out = B.unbucketize(lay, buckets, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=6)
@given(st.integers(64, 4096), st.integers(1, 8), st.sampled_from([8, 32, 128]))
def test_layout_invariants(bucket_bytes, max_buckets, row):
    tree = _tree(KEY, [(37, 11), (5,), (301,), (2, 3, 7)])
    lay = B.make_layout(tree, bucket_bytes=bucket_bytes,
                        max_buckets=max_buckets, row=row)
    # equal-size buckets, row-aligned, capped count, padding < one bucket
    assert lay.n_buckets <= max_buckets
    assert lay.bucket_elems % row == 0
    assert 0 <= lay.pad < lay.bucket_elems
    assert lay.padded_total == lay.n_buckets * lay.bucket_elems
    assert lay.total == sum(lay.sizes)
    # tail padding is zero-filled and roundtrip drops it
    buckets = B.bucketize(lay, tree)
    flat = np.asarray(buckets).reshape(-1)
    if lay.pad:
        assert (flat[lay.total:] == 0).all()


def test_single_bucket_cap():
    """bucket_bytes=inf collapses to ONE tree-sized bucket, not a giant one."""
    tree = _tree(KEY, [(33, 3), (41,)])
    lay = B.make_layout(tree, bucket_bytes=1 << 40, max_buckets=16, row=32)
    assert lay.n_buckets == 1
    assert lay.bucket_elems < lay.total + lay.row + 32


def test_bucketize_is_linear():
    tree_a = _tree(KEY, [(9, 5), (44,)])
    tree_b = jax.tree.map(lambda x: 2.0 * x + 1.0, tree_a)
    lay = B.make_layout(tree_a, bucket_bytes=128, max_buckets=8, row=8)
    lhs = B.bucketize(lay, jax.tree.map(jnp.add, tree_a, tree_b))
    rhs = B.bucketize(lay, tree_a) + B.bucketize(lay, tree_b)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-6)


def test_bucket_keys_distinct():
    keys = np.asarray(B.bucket_keys(KEY, 8))
    assert len({tuple(k) for k in keys}) == 8


def test_bucket_encode_decode_unbiased_scale():
    """Per-bucket encode matches per-row squant semantics bucket by bucket."""
    tree = _tree(KEY, [(64, 32), (128,)])
    lay = B.make_layout(tree, bucket_bytes=1024, max_buckets=8, row=64)
    buckets = B.bucketize(lay, tree)
    q, sc = dist.bucket_encode(KEY, buckets, s=3)
    assert q.shape == lay.shape and q.dtype == jnp.int8
    assert sc.shape == (lay.n_buckets, lay.rows, 1)
    keys = B.bucket_keys(KEY, lay.n_buckets)
    for b in range(lay.n_buckets):
        qb, sb = dist.squant_encode(keys[b], buckets[b], 3)
        np.testing.assert_array_equal(np.asarray(q[b]), np.asarray(qb))
        np.testing.assert_allclose(np.asarray(sc[b]), np.asarray(sb))


# ---------------------------------------------------------------------------
# kernels/bucket_ring.py
# ---------------------------------------------------------------------------

def _payload(key, n, b, r, c):
    kq, ks = jax.random.split(key)
    q = jax.random.randint(kq, (n, b, r, c), -4, 5, jnp.int8)
    sc = jax.random.uniform(ks, (n, b, r, 1), jnp.float32)
    return q, sc


def test_bucket_acc_matches_oracle():
    q, sc = _payload(KEY, 1, 3, 8, 16)
    acc = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 16))
    out = BK.bucket_acc(acc, q[0], sc[0])
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(BK.bucket_acc_ref(acc, q[0], sc[0])),
                               atol=1e-6)


def test_bucket_acc_block_rows():
    q, sc = _payload(KEY, 1, 2, 8, 16)
    acc = jnp.zeros((2, 8, 16))
    full = BK.bucket_acc(acc, q[0], sc[0])
    blocked = BK.bucket_acc(acc, q[0], sc[0], block_rows=4)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(blocked))


def test_bucket_ring_sum_matches_hop_chain():
    """The all-at-once kernel == the hop-by-hop bucket_acc chain (up to FMA
    fusion inside one kernel body ~1e-7)."""
    q, sc = _payload(KEY, 5, 4, 8, 16)
    stacked = BK.bucket_ring_sum(q, sc)
    acc = jnp.zeros((4, 8, 16), jnp.float32)
    for i in range(5):
        acc = BK.bucket_acc(acc, q[i], sc[i])
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(acc),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(stacked),
                               np.asarray(BK.bucket_ring_sum_ref(q, sc)),
                               atol=1e-5)
