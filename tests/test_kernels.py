"""Per-kernel tests: shape/dtype sweeps vs the pure-jnp oracle (ref.py),
plus statistical properties of the ops-level API."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.prop import given, settings, st

from repro.kernels import ops, ref
from repro.kernels import squant as sq
from repro.kernels import fused_memory as fm

KEY = jax.random.PRNGKey(0)

SHAPES = [(256, 256), (512, 256), (256, 512)]
BLOCKS = [(256, 256), (128, 256)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(seed + 1), shape, jnp.float32)
    return x.astype(dtype), u.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("block", BLOCKS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("s", [1, 4])
def test_encode_matches_ref(shape, block, dtype, s):
    bm, bn = block
    if shape[0] % bm or shape[1] % bn:
        pytest.skip("non-multiple")
    x, u = _mk(shape, dtype)
    q, scales = sq.squant_encode(x, u, s=s, block=block, interpret=True)
    qr, sr = ref.squant_encode_ref(x, u, s, bm, bn)
    # f32 accumulation-order differences may flip a stochastic-rounding
    # threshold on a vanishingly small fraction of coordinates
    qn, qrn = np.asarray(q, np.int32), np.asarray(qr, np.int32)
    mismatch = qn != qrn
    assert mismatch.mean() < 1e-4, mismatch.mean()
    assert np.abs(qn - qrn)[mismatch].max(initial=0) <= 1
    np.testing.assert_allclose(np.asarray(scales), np.asarray(sr),
                               rtol=3e-3 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("shape", SHAPES[:2])
@pytest.mark.parametrize("dtype", DTYPES)
def test_decode_matches_ref(shape, dtype):
    block = (256, 256)
    x, u = _mk(shape, jnp.float32, seed=3)
    q, scales = sq.squant_encode(x, u, s=2, block=block, interpret=True)
    out = sq.squant_decode(q, scales, block=block, dtype=dtype, interpret=True)
    outr = ref.squant_decode_ref(q, scales, *block, dtype=dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(outr, np.float32), rtol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("s", [1, 3])
@pytest.mark.parametrize("alpha", [0.25, 0.5])
def test_fused_memory_matches_ref(shape, s, alpha):
    block = (256, 256)
    g, u = _mk(shape, jnp.float32, seed=5)
    h, _ = _mk(shape, jnp.float32, seed=6)
    q, scales, h_new = fm.fused_memory_update(g, h, u, alpha, s=s, block=block,
                                              interpret=True)
    qr, sr, hr = ref.fused_memory_ref(g, h, u, alpha, s, *block)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h_new), np.asarray(hr), rtol=1e-5, atol=1e-6)


def test_dequant_apply_matches_ref():
    block = (256, 256)
    w, u = _mk((512, 256), jnp.float32, seed=7)
    x, _ = _mk((512, 256), jnp.float32, seed=8)
    q, scales = sq.squant_encode(x, u, s=1, block=block, interpret=True)
    out = sq.dequant_apply(w, q, scales, 0.1, block=block, interpret=True)
    outr = ref.dequant_apply_ref(w, q, scales, 0.1, *block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# ops-level (arbitrary shapes, padding, pytrees)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (100,), (33, 65), (3, 5, 129), (300000,)])
def test_ops_roundtrip_shapes(shape):
    x = jax.random.normal(KEY, shape)
    out = ops.compress(KEY, x, s=1)
    assert out.shape == x.shape and out.dtype == x.dtype
    # dequantized values share sign or are zero
    xn, on = np.asarray(x), np.asarray(out)
    bad = (np.sign(on) != 0) & (np.sign(on) != np.sign(xn))
    assert not bad.any()


def test_ops_unbiased():
    """E[C(x)] = x, checked via per-coordinate z-scores (the per-sample std is
    large by design for s=1: ~scale*sqrt(p))."""
    n_samp = 150
    x = jax.random.normal(KEY, (768,))
    keys = jax.random.split(jax.random.PRNGKey(1), n_samp)
    outs = jax.vmap(lambda k: ops.compress(k, x, s=1))(keys)
    # projection statistic: t_k = <C_k(x), x>/||x||^2 has mean 1 if unbiased
    t = np.asarray(outs @ x / jnp.sum(x * x))
    z = (t.mean() - 1.0) / (t.std(ddof=1) / np.sqrt(n_samp))
    assert abs(z) < 5.0, (t.mean(), z)


def test_ops_variance_bound():
    """Per-tile squant satisfies Assumption 5 with omega = sqrt(tile)/s."""
    d = 256 * 256   # one tile exactly
    x = jax.random.normal(KEY, (d,))
    keys = jax.random.split(jax.random.PRNGKey(2), 50)
    errs = jax.vmap(lambda k: jnp.sum((ops.compress(k, x, s=1) - x) ** 2))(keys)
    omega = np.sqrt(d) / 1.0
    assert float(jnp.mean(errs)) <= omega * float(jnp.sum(x**2)) * 1.1


def test_ops_memory_update_consistency():
    """ops.memory_update == unfused encode/decode pipeline on same bits."""
    g = jax.random.normal(KEY, (500, 300))
    h = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (500, 300))
    dh, h_new, c = ops.memory_update(jax.random.PRNGKey(4), g, h, 0.5, s=1)
    np.testing.assert_allclose(np.asarray(h + 0.5 * dh), np.asarray(h_new),
                               rtol=1e-5, atol=1e-6)


def test_tree_memory_update():
    tree_g = {"w": jax.random.normal(KEY, (64, 32)), "b": jnp.ones((17,))}
    tree_h = jax.tree.map(jnp.zeros_like, tree_g)
    dh, hn = ops.tree_memory_update(KEY, tree_g, tree_h, 0.5, s=1)
    assert jax.tree.structure(dh) == jax.tree.structure(tree_g)
    for a, b in zip(jax.tree.leaves(hn), jax.tree.leaves(dh)):
        np.testing.assert_allclose(np.asarray(a), 0.5 * np.asarray(b), rtol=1e-6)


def test_apply_update():
    w = jax.random.normal(KEY, (100, 100))
    g = jax.random.normal(jax.random.PRNGKey(9), (100, 100))
    c, shape = ops.encode(jax.random.PRNGKey(10), g, s=1)
    w2 = ops.apply_update(w, c, 0.01, shape)
    expect = w - 0.01 * ops.decode(c, shape)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(expect), rtol=1e-5, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4000), st.integers(1, 126), st.integers(0, 10**6))
def test_property_roundtrip_grid(n, s, seed):
    """Every decoded coordinate is a multiple of its tile scale, within level bound."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    c, shape = ops.encode(jax.random.PRNGKey(seed + 1), x, s=s)
    out = np.asarray(ops.decode(c, shape))
    q = np.asarray(c.q)
    assert np.abs(q).max() <= s + 1
    # decode is exactly q*scale per tile:
    full = np.asarray(ops.decode(c, (c.q.size,)))
    assert full.shape == (c.q.size,)


# ---------------------------------------------------------------------------
# ring_sum (server-side dequant-accumulate)
# ---------------------------------------------------------------------------

from repro.kernels import ring_sum as rs


@pytest.mark.parametrize("n", [2, 4, 8])
@pytest.mark.parametrize("shape", [(256, 256), (512, 256)])
def test_ring_sum_matches_ref(n, shape):
    q = jax.random.randint(jax.random.PRNGKey(n), (n,) + shape, -3, 4,
                           dtype=jnp.int8)
    scales = jax.random.uniform(jax.random.PRNGKey(n + 1), (n, shape[0], 1))
    out = rs.ring_sum(q, scales, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(rs.ring_sum_ref(q, scales)),
                               rtol=1e-6, atol=1e-5)


def test_ring_sum_roundtrip_consistency():
    """ring_sum of encoded worker deltas == sum of decoded deltas."""
    from repro.core import dist as D
    n, m, c = 4, 256, 256
    xs = jax.random.normal(jax.random.PRNGKey(0), (n, m, c))
    qs, ss = [], []
    for i in range(n):
        q, s_ = D.squant_encode(jax.random.PRNGKey(i + 1), xs[i], 1)
        qs.append(q)
        ss.append(s_)
    out = rs.ring_sum(jnp.stack(qs), jnp.stack(ss), interpret=True)
    expect = sum(D.squant_decode(q, s_) for q, s_ in zip(qs, ss))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5)
