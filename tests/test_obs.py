"""repro.obs tests (DESIGN.md §11).

Pins, in order of importance:
  1. disabled telemetry is a no-op: ``telemetry=True`` vs ``False`` produce
     BITWISE-identical trajectories/bits/distances (the carry is appended,
     never mixed into the math), and ``telemetry=False`` — the default every
     pre-existing test runs under — leaves ``res.telemetry`` None;
  2. the counters mean what they claim: the bit-ledger counters reconcile
     exactly against ``res.bits``, participation counters against the
     availability draw, the error histogram against the round count, and
     rollback counts survive the sentinel's carry restore;
  3. the JSONL event log round-trips: write -> read -> validate (zero
     schema errors) -> summarize, including rollback events of a faulted
     run; the schema actually rejects malformed events;
  4. the bench ledger gates: first entry is baseline, within-tolerance is
     ok, beyond-tolerance is a regression (both directions);
  5. spans ledger + sink mirroring + the mesh wire-byte reconciliation
     (subprocess, 8 fake CPU devices — tests/helpers/bucket_scenarios.py).
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import artemis as art
from repro.core import faults
from repro.core import federated as fed
from repro.core import sweep as sw
from repro.obs import bench, events, spans
from repro.obs import telemetry as T

KEY = jax.random.PRNGKey(42)
N, D = 8, 16


@pytest.fixture(scope="module")
def prob_star():
    prob, w_star = fed.make_lsr_problem(KEY, n_workers=N, n_per=50, d=D,
                                        noise=0.0)
    return prob, w_star


def _cfgs():
    plain = art.variant_config("artemis", D, N, s=1, p=1.0)
    pp = art.variant_config("artemis", D, N, s=1, p=0.5)
    return [plain, pp]


def _run(prob, cfgs, w_star=None, iters=40, eval_every=10, **kw):
    return sw.run_sweep(prob, cfgs, [0.02], [0, 1], iters=iters, batch=4,
                        eval_every=eval_every, w_star=w_star, **kw)


# ---------------------------------------------------------------------------
# 1. bitwise neutrality
# ---------------------------------------------------------------------------

def test_telemetry_off_by_default_and_none(prob_star):
    prob, w_star = prob_star
    res = _run(prob, _cfgs(), w_star)
    assert res.telemetry is None


def test_telemetry_is_bitwise_neutral(prob_star):
    """The tentpole acceptance bar: enabling telemetry changes NOTHING about
    the computation — losses, bits, distances, final iterates all bitwise
    equal to the telemetry-free program (which is itself the pre-obs
    program: the carry is statically absent when off)."""
    prob, w_star = prob_star
    cfgs = _cfgs()
    off = _run(prob, cfgs, w_star)
    on = _run(prob, cfgs, w_star, telemetry=True)
    np.testing.assert_array_equal(off.losses, on.losses)
    np.testing.assert_array_equal(off.bits, on.bits)
    np.testing.assert_array_equal(off.dists, on.dists)
    np.testing.assert_array_equal(off.w_final, on.w_final)
    assert on.telemetry is not None


def test_telemetry_neutral_under_faults_and_rollback(prob_star):
    """Same neutrality with the whole fault + sentinel machinery engaged
    (the telemetry carry must stay OUT of the rollback snapshot)."""
    prob, _ = prob_star
    fc = faults.FaultConfig(blowup_rate=0.1, blowup_value=1e15, scrub=True,
                            sentinel=1e3, backoff=0.5)
    cfg = dataclasses.replace(art.variant_config("artemis", D, N, s=1, p=0.7),
                              faults=fc)
    off = _run(prob, [cfg])
    on = _run(prob, [cfg], telemetry=True)
    np.testing.assert_array_equal(off.losses, on.losses)
    np.testing.assert_array_equal(off.w_final, on.w_final)
    np.testing.assert_array_equal(off.rollbacks, on.rollbacks)
    assert int(off.rollbacks.sum()) >= 1, "scenario never rolled back"


# ---------------------------------------------------------------------------
# 2. counter semantics
# ---------------------------------------------------------------------------

def test_bit_ledger_reconciles_exactly(prob_star):
    """uplink_bits + catchup_bits is the same ledger res.bits reports —
    counted independently inside the telemetry carry."""
    prob, w_star = prob_star
    res = _run(prob, _cfgs(), w_star, telemetry=True)
    tel = res.telemetry
    total = tel["uplink_bits"][..., -1] + tel["catchup_bits"][..., -1]
    np.testing.assert_allclose(total, res.bits[..., -1], rtol=1e-6)


def test_participation_and_hist_counts(prob_star):
    prob, w_star = prob_star
    iters = 40
    res = _run(prob, _cfgs(), w_star, iters=iters, telemetry=True)
    tel = res.telemetry
    # full participation: every worker available & active every round
    assert np.all(tel["avail"][0, ..., -1] == N * iters)
    assert np.all(tel["active"][0, ..., -1] == N * iters)
    # p=0.5 cell: strictly fewer, and avail == active (no faults configured)
    assert np.all(tel["avail"][1, ..., -1] < N * iters)
    np.testing.assert_array_equal(tel["avail"][1], tel["active"][1])
    # one histogram observation per round, cumulative across eval points
    hist = tel["err_up_hist"]
    np.testing.assert_allclose(hist[..., -1, :].sum(axis=-1), iters)
    # counters are monotone in the eval axis
    assert np.all(np.diff(tel["uplink_bits"], axis=-1) >= 0)


def test_rollback_counter_survives_restore(prob_star):
    """The sentinel restores the pre-divergence carry; the telemetry carry
    is outside that snapshot, so the rollback count (and the fault counters
    that caused it) persist."""
    prob, _ = prob_star
    fc = faults.FaultConfig(blowup_rate=0.1, blowup_value=1e15, scrub=True,
                            sentinel=1e3, backoff=0.5)
    cfg = dataclasses.replace(art.variant_config("artemis", D, N, s=1, p=0.7),
                              faults=fc)
    res = _run(prob, [cfg], telemetry=True)
    tel = res.telemetry
    rb = res.rollbacks[0]
    assert int(rb.sum()) >= 1
    np.testing.assert_array_equal(tel["rollbacks"][0, ..., -1], rb)
    assert np.all(tel["blowup_hits"][0, ..., -1] >= 1)


def test_memory_drift_shrinks_noiseless(prob_star):
    """Noiseless LSR: h_i -> grad F_i(w*), so the paper's memory-drift term
    must shrink over training (this is the quantity behind the linear-rate
    threshold — the reason the gauge exists)."""
    prob, w_star = prob_star
    res = _run(prob, _cfgs(), w_star, iters=200, eval_every=50,
               telemetry=True)
    drift = res.telemetry["mem_drift"][0, 0, 0]
    assert drift[-1] < 0.5 * drift[0], drift


# ---------------------------------------------------------------------------
# 3. JSONL round-trip + schema
# ---------------------------------------------------------------------------

def test_events_roundtrip_faulted_sweep(prob_star, tmp_path):
    prob, _ = prob_star
    fc = faults.FaultConfig(blowup_rate=0.1, blowup_value=1e15, scrub=True,
                            sentinel=1e3, backoff=0.5)
    cfg = dataclasses.replace(art.variant_config("artemis", D, N, s=1, p=0.7),
                              faults=fc)
    res = _run(prob, [cfg], telemetry=True)
    path = str(tmp_path / "events.jsonl")
    with events.EventLog(path) as log:
        log.start(config={"iters": 40}, fingerprint="test")
        n = events.record_sweep(log, res, cfgs=[cfg])
        log.end(status="ok", wall_s=0.0)
    assert n >= res.losses.size
    evs = events.read_events(path)
    assert events.validate_events(evs) == []
    s = events.summarize(evs)
    assert s["schema_errors"] == [] and s["status"] == "ok"
    # the faulted run's rollbacks surfaced as first-class events
    assert s["rollbacks"] == int(res.rollbacks.sum()) >= 1
    # per-cell final numbers match the arrays they came from
    for (v, g, sd), cell in ((tuple(map(int, k.split("/"))), c)
                             for k, c in s["cells"].items()):
        assert cell["loss"] == float(res.losses[v, g, sd, -1])
        assert cell["metrics"]["rollbacks"] == float(
            res.telemetry["rollbacks"][v, g, sd, -1])


def test_event_schema_rejects_malformed(tmp_path):
    log = events.EventLog(str(tmp_path / "e.jsonl"))
    with pytest.raises(ValueError, match="unknown event type"):
        log.emit("nonsense", x=1)
    with pytest.raises(ValueError, match="missing required field"):
        log.emit("eval", cell={}, iter=0, loss=1.0, bits=0.0)  # no dist
    with pytest.raises(ValueError, match="not in the catalogue"):
        log.emit("eval", cell={}, iter=0, loss=1.0, bits=0.0, dist=0.0,
                 metrics={"no_such_metric": 1.0})
    with pytest.raises(ValueError, match="must be a list"):
        log.emit("eval", cell={}, iter=0, loss=1.0, bits=0.0, dist=0.0,
                 metrics={"err_up_hist": 3.0})
    log.close()


def test_catalogue_is_closed_registry():
    names = {m.name for m in T.catalogue()}
    assert set(T.SWEEP_METRICS) <= names and set(T.MESH_METRICS) <= names
    with pytest.raises(ValueError, match="already registered differently"):
        T.register(T.Metric("err_up", "counter", "conflicting redefinition"))


# ---------------------------------------------------------------------------
# 4. bench ledger gate
# ---------------------------------------------------------------------------

def test_bench_gate_baseline_ok_regression(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    bench.append(path, "wall_s", 10.0, "s", tol=0.25)
    assert [v.status for v in bench.check(path)] == ["baseline"]
    bench.append(path, "wall_s", 11.0, "s", tol=0.25)      # +10% < 25%
    assert [v.status for v in bench.check(path)] == ["ok"]
    bench.append(path, "wall_s", 14.0, "s", tol=0.25)      # +40% vs best=10
    v, = bench.check(path)
    assert v.status == "regression" and v.best == 10.0
    assert [r.name for r in bench.regressions(path)] == ["wall_s"]


def test_bench_gate_higher_direction_and_exact(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    bench.append(path, "tok_s", 100.0, "tok/s", direction="higher", tol=0.2)
    bench.append(path, "tok_s", 90.0, "tok/s", direction="higher", tol=0.2)
    assert bench.check(path)[0].status == "ok"               # -10% > -20%
    bench.append(path, "tok_s", 70.0, "tok/s", direction="higher", tol=0.2)
    assert bench.check(path)[0].status == "regression"
    # tol=0 pins deterministic metrics exactly
    bench.append(path, "schema_errors", 0.0, "count", tol=0.0)
    bench.append(path, "schema_errors", 0.0, "count", tol=0.0)
    assert bench.check(path, names=["schema_errors"])[0].status == "ok"
    bench.append(path, "schema_errors", 1.0, "count", tol=0.0)
    assert bench.check(path, names=["schema_errors"])[0].status == \
        "regression"
    with pytest.raises(ValueError):
        bench.append(path, "x", 1.0, "", direction="sideways")


# ---------------------------------------------------------------------------
# 5. spans + sink, mesh wire telemetry (subprocess)
# ---------------------------------------------------------------------------

def test_spans_ledger_and_sink(tmp_path):
    spans.reset()
    path = str(tmp_path / "s.jsonl")
    with events.EventLog(path) as log:
        spans.install_sink(log)
        try:
            with spans.span("outer"):
                with spans.span("inner"):
                    pass
        finally:
            spans.uninstall_sink()
    recs = spans.records()
    assert [r.name for r in recs[-2:]] == ["inner", "outer"]
    assert recs[-2].depth == 1 and recs[-1].depth == 0
    assert spans.total("outer") >= spans.total("inner") >= 0.0
    evs = events.read_events(path)
    assert [e["name"] for e in evs] == ["inner", "outer"]
    assert events.validate_events(evs) == []
    agg = {a["name"]: a for a in spans.summarize_spans(recs[-2:])}
    assert agg["outer"]["count"] == 1


def test_compile_execute_split():
    spans.reset()
    fn = jax.jit(lambda x: (x * 2.0).sum())
    out = spans.compile_execute_split(fn, jnp.arange(128.0))
    assert out["first_call_s"] >= out["execute_s"] > 0.0
    assert out["compile_s"] == pytest.approx(
        out["first_call_s"] - out["execute_s"])


def test_mesh_wire_telemetry_subprocess():
    """wire_bytes matches the codec-derived roofline model on both mesh
    wires (8 fake CPU devices; see scenario_obs_wire_telemetry)."""
    helper = os.path.join(os.path.dirname(__file__), "helpers",
                          "bucket_scenarios.py")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, helper, "obs_wire_telemetry"],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, \
        f"\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    assert "scenario obs_wire_telemetry: OK" in proc.stdout


# ---------------------------------------------------------------------------
# CLI: summarize / validate / dashboard / bench round-trip
# ---------------------------------------------------------------------------

def test_cli_validate_and_summary(prob_star, tmp_path):
    prob, w_star = prob_star
    res = _run(prob, _cfgs(), w_star, telemetry=True)
    path = str(tmp_path / "events.jsonl")
    with events.EventLog(path) as log:
        log.start(config={}, fingerprint="cli-test")
        events.record_sweep(log, res, cfgs=_cfgs())
        log.end(status="ok", wall_s=1.0)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    for args in (["validate", path], ["summary", path, "--json"],
                 ["dashboard", path, "-o", str(tmp_path / "dash.md")]):
        proc = subprocess.run([sys.executable, "-m", "repro.obs", *args],
                              capture_output=True, text=True, timeout=300,
                              env=env)
        assert proc.returncode == 0, (args, proc.stdout, proc.stderr[-2000:])
    dash = open(tmp_path / "dash.md").read()
    assert "bits" in dash and "loss" in dash
    s = json.loads(subprocess.run(
        [sys.executable, "-m", "repro.obs", "summary", path, "--json"],
        capture_output=True, text=True, env=env).stdout)
    assert s["schema_errors"] == [] and s["cells"]
