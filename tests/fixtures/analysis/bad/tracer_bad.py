"""Seeded violation: tracer-python-branch."""
import jax.numpy as jnp


def branch_on_tracer(x):
    if jnp.any(x > 0):                        # ConcretizationError under jit
        return x * 2
    while jnp.sum(x) < 1.0:                   # same, in a while test
        x = x + 1
    return x
