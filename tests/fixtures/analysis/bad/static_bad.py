"""Seeded violation: static-unhashable-default."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("dims",))
def reduce_over(x, dims=[0]):                 # unhashable static default
    return x.sum(axis=tuple(dims))
