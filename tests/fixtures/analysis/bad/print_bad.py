"""Seeded print-in-library violation: a library helper that narrates its
progress with bare print() calls instead of routing through the obs event
sink (or living in a __main__ CLI module)."""


def run_epoch(step: int, loss: float) -> float:
    print(f"step {step}: loss={loss:.4f}")
    if loss > 1e3:
        print("loss blew up, clipping")
        loss = 1e3
    return loss
