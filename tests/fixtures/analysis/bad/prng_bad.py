"""Seeded violations: prng-key-reuse (twice) and prng-split-overflow."""
import jax


def reuse_whole_key(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))        # reuse of `key`
    return a + b


def reuse_split_slot(key):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[1], (4,))
    y = jax.random.normal(ks[1], (4,))       # reuse of ks[1]
    return x + y


def overflow_split(key):
    ks = jax.random.split(key, 3)
    return jax.random.normal(ks[3], (4,))    # ks[3] past split(..., 3)
