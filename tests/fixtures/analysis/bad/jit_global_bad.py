"""Seeded violations: jit-mutable-global (global stmt + mutable closure)."""
import jax

_CALLS = 0
_CACHE = {}


@jax.jit
def counted(x):
    global _CALLS
    _CALLS += 1                               # trace-time only
    return x * 2


@jax.jit
def cached_scale(x):
    return x * _CACHE.get("scale", 1.0)       # baked in at trace time
