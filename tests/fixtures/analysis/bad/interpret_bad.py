"""Seeded violation: hardcoded-interpret."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def double_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def double(x):
    return pl.pallas_call(
        double_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,                       # pins interpret mode
    )(x)
