"""Clean twin of interpret_bad: interpret routed through the env-aware
default (and plumbed as a value, never a literal)."""
import jax
from jax.experimental import pallas as pl

from repro.kernels import default_interpret


def double_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def double(x, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return pl.pallas_call(
        double_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
