"""Clean twin of jit_global_bad: state threaded through arguments; the
module mutable is only touched outside jit."""
import jax

_CALLS = 0
_CACHE = {}


@jax.jit
def pure_fn(x, scale):
    return x * scale


def record_call(x):
    global _CALLS                             # not jit-wrapped: fine
    _CALLS += 1
    return pure_fn(x, _CACHE.get("scale", 1.0))
