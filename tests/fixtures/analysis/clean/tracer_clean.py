"""Clean twin of tracer_bad: lax control flow + dtype-metadata branches."""
import jax.numpy as jnp


def no_tracer_branch(x):
    y = jnp.where(jnp.any(x > 0), x * 2, x)
    if jnp.issubdtype(x.dtype, jnp.floating):  # metadata query: fine
        y = y.astype(jnp.float32)
    if x.ndim == 2:                            # python int: fine
        y = y.sum(axis=0)
    return y
