"""Clean twin of static_bad: hashable tuple default."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("dims",))
def reduce_over(x, dims=(0,)):
    return x.sum(axis=tuple(dims))
