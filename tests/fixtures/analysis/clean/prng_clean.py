"""Clean twin of prng_bad: every key consumed exactly once; fold_in side
streams and branch-exclusive consumption are idiomatic, not reuse."""
import jax


def no_reuse(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.uniform(k2, (4,))
    salted = jax.random.fold_in(k2, 7)       # weak consumption: fine
    c = jax.random.normal(salted, (4,))
    return a + b + c


def branch_exclusive(key, flag):
    if flag:
        return jax.random.normal(key, (4,))
    else:
        return jax.random.uniform(key, (4,))  # other branch: not reuse


def rebound_generation(key):
    key, sub = jax.random.split(key)
    x = jax.random.normal(sub, (4,))
    key, sub = jax.random.split(key)          # fresh generation of `sub`
    return x + jax.random.normal(sub, (4,))


def in_range_split(key):
    ks = jax.random.split(key, 3)
    return jax.random.normal(ks[2], (4,))
