"""Clean twin of print_bad.py: the same progress narration routed through
the schema-checked event sink — ``echo=True`` mirrors to the console, so
nothing is lost, and the output is machine-readable JSONL."""
from repro.obs import events


def run_epoch(log: events.EventLog, step: int, loss: float) -> float:
    log.emit("train_step", step=step, loss=loss, wall_s=0.0)
    if loss > 1e3:
        log.emit("note", text="loss blew up, clipping")
        loss = 1e3
    return loss
