"""Per-architecture smoke tests: REDUCED variant of each assigned family runs
one forward/train step + one decode step on CPU; shapes + no NaNs asserted.

XLA-CPU compile time dominates (~5-15 s per arch), so only two representative
architectures (dense transformer + SSM) run in the default tier-1 set; the
rest carry the ``slow`` marker and run in the CI full stage (``-m slow``).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 128

# default-tier coverage: one dense transformer (SSM/MoE layer math is unit-
# tested directly in test_layers.py / test_moe.py; full zoo runs via -m slow)
FAST_ARCHS = {"starcoder2-7b"}


def _arch_params(archs):
    return [pytest.param(a, marks=() if a in FAST_ARCHS else
                         (pytest.mark.slow,)) for a in sorted(archs)]


@functools.lru_cache(maxsize=None)
def _model_and_params(arch):
    """Share the built model + init across the train/decode/prefill tests."""
    cfg = configs.get_config(arch, reduced=True)
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _batch(cfg):
    kt = jax.random.PRNGKey(1)
    if cfg.family == "vlm":
        return {
            "tokens": jax.random.randint(kt, (B, S - cfg.n_patches), 0, cfg.vocab),
            "embeds": jax.random.normal(kt, (B, cfg.n_patches, cfg.d_model)),
        }
    if cfg.family == "encdec":
        return {
            "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
            "frames": jax.random.normal(kt, (B, cfg.n_frames, cfg.d_model)),
        }
    return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", _arch_params(configs.ARCHS))
def test_train_step(arch):
    cfg, model, params = _model_and_params(arch)
    batch = _batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        return loss, metrics, grads

    loss, metrics, grads = step(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    # a near-uniform untrained model should sit near log(vocab)
    assert float(metrics["nll"]) < np.log(cfg.vocab) + 2.0
    gnorm = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32)**2)
                               for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", _arch_params(configs.ARCHS))
def test_decode_step(arch):
    cfg, model, params = _model_and_params(arch)
    cache = model.init_cache(B, 64)
    token = jnp.zeros((B,), jnp.int32)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = jax.random.normal(KEY, (B, cfg.n_frames, cfg.d_model)
                                    ).astype(cfg.cdtype)

    @jax.jit
    def step(p, c, t, pos):
        return model.decode_step(p, c, t, pos, enc_out=enc_out)

    logits, cache = step(params, cache, token, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    logits2, cache = step(params, cache, token + 1, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2)).all(), arch
    # different input token must change the output
    assert not jnp.array_equal(logits, logits2), arch


@pytest.mark.parametrize("arch", _arch_params(["starcoder2-7b",
                                               "falcon-mamba-7b",
                                               "recurrentgemma-2b",
                                               "mixtral-8x22b"]))
def test_decode_matches_prefill(arch):
    """Greedy decode step-by-step == teacher-forced forward (same tokens)."""
    import dataclasses
    cfg = configs.get_config(arch, reduced=True)
    if cfg.family == "moe":
        # capacity dropping differs between prefill/decode token grouping;
        # use a dropless capacity factor for the consistency check
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
        model = build_model(cfg)
        params = model.init(KEY)
    else:
        cfg, model, params = _model_and_params(arch)
    T = 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)

    # teacher-forced hidden states -> logits at each position
    x, _, _ = model._forward(params, {"tokens": toks})
    from repro.models.model import _cast
    full_logits = np.asarray(
        (x @ _cast(params["unembed"], cfg.cdtype)).astype(jnp.float32))

    cache = model.init_cache(B, T)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    for i in range(T):
        logits, cache = step(params, cache, toks[:, i], jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits), full_logits[:, i],
                                   rtol=5e-2, atol=5e-2)


@pytest.mark.slow
def test_sliding_window_ring_buffer():
    """Mixtral-reduced: decode beyond the window keeps cache size fixed and
    only attends to the last `window` tokens."""
    cfg, model, params = _model_and_params("mixtral-8x22b")
    cache = model.init_cache(B, 4096)   # request long; ring caps at window
    k_shape = jax.tree.leaves(cache)[0].shape
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    logits, cache = step(params, cache, jnp.zeros((B,), jnp.int32),
                         jnp.int32(cfg.window + 5))
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.leaves(cache)[0].shape == k_shape


def test_long_500k_skips():
    shp = configs.SHAPES["long_500k"]
    runs = {a for a in configs.ARCHS
            if configs.applicable(configs.get_config(a), shp) is None}
    assert runs == {"falcon-mamba-7b", "recurrentgemma-2b", "mixtral-8x22b"}
