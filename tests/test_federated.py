"""Integration tests: the paper's convergence claims on the simulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import artemis as art, federated as fed
from repro.core import compression as comp

KEY = jax.random.PRNGKey(42)


@pytest.fixture(scope="module")
def lsr_noiseless():
    prob, _ = fed.make_lsr_problem(KEY, n_workers=10, n_per=100, d=20, noise=0.0)
    return prob


@pytest.fixture(scope="module")
def lsr_noisy():
    prob, _ = fed.make_lsr_problem(KEY, n_workers=10, n_per=100, d=20, noise=0.4)
    return prob


def test_linear_convergence_sigma_star_zero(lsr_noiseless):
    """Thm 1: sigma_*=0 => linear convergence for ALL variants (E=0 floor)."""
    for variant in ["sgd", "qsgd", "diana", "biqsgd"]:
        cfg = art.variant_config(variant, 20, 10)
        g = fed.gamma_max(lsr_noiseless, cfg)
        r = fed.run(lsr_noiseless, cfg, gamma=g, iters=400, key=KEY, batch=8)
        assert r.losses[-1] < 1e-5, (variant, r.losses[-1])


def test_saturation_ordering_sigma_star_nonzero(lsr_noisy):
    """Fig 3a: with sigma_* != 0 all algorithms saturate; double compression
    saturates higher than single, higher than SGD (at a shared step size)."""
    gamma = 1.0 / (4 * lsr_noisy.smoothness())
    floors = {}
    for variant in ["sgd", "qsgd", "biqsgd"]:
        cfg = art.variant_config(variant, 20, 10)
        r = fed.run(lsr_noisy, cfg, gamma=gamma, iters=600, key=KEY, batch=1)
        floors[variant] = float(np.mean(r.losses[-100:]))
    opt = float(lsr_noisy.global_loss(lsr_noisy.solve_opt()))
    assert floors["sgd"] - opt < floors["qsgd"] - opt < floors["biqsgd"] - opt


def test_memory_helps_non_iid():
    """Fig 3b / S9: non-i.i.d. full-batch (sigma_*=0): memory converges
    linearly, memoryless bidirectional saturates at a high level."""
    prob = fed.make_logistic_problem(jax.random.PRNGKey(3), n_workers=10, n_per=200, d=2)
    gamma = 1.0 / (2 * prob.smoothness())
    res = {}
    for variant in ["artemis", "biqsgd"]:
        cfg = art.variant_config(variant, 2, 10)
        r = fed.run(prob, cfg, gamma=gamma, iters=800, key=KEY, full_batch=True)
        res[variant] = r
    opt = float(prob.global_loss(prob.solve_opt()))
    exc_mem = res["artemis"].losses[-1] - opt
    exc_nomem = res["biqsgd"].losses[-1] - opt
    assert exc_mem < exc_nomem / 5, (exc_mem, exc_nomem)


def test_pp2_beats_pp1():
    """Fig 5/6: partial participation, full gradients, non-iid: PP1 saturates,
    PP2 converges linearly."""
    prob = fed.make_logistic_problem(jax.random.PRNGKey(5), n_workers=10, n_per=200, d=2)
    gamma = 1.0 / (2 * prob.smoothness())
    res = {}
    for mode in ["pp1", "pp2"]:
        cfg = art.ArtemisConfig(dim=2, n_workers=10, up="identity", dwn="identity",
                                alpha=0.5, p=0.5, pp_mode=mode)
        r = fed.run(prob, cfg, gamma=gamma, iters=800, key=KEY, full_batch=True)
        res[mode] = float(np.mean(r.losses[-50:]))
    opt = float(prob.global_loss(prob.solve_opt()))
    assert res["pp2"] - opt < (res["pp1"] - opt) / 5, res


def test_bidirectional_bit_savings(lsr_noiseless):
    """App A.1: bi-compression ~ O(sqrt(d) log d) per direction vs O(d)."""
    bits = {}
    for variant in ["sgd", "artemis"]:
        cfg = art.variant_config(variant, 20, 10)
        r = fed.run(lsr_noiseless, cfg, gamma=0.01, iters=50, key=KEY, batch=4)
        bits[variant] = r.bits[-1]
    assert bits["artemis"] < bits["sgd"] / 2


def test_polyak_ruppert_tail_average(lsr_noisy):
    """Thm 2 (qualitatively): once in the stationary regime, averaging reduces
    the excess loss vs the oscillating last iterate."""
    cfg = art.variant_config("qsgd", 20, 10)
    g = 1.0 / (3 * lsr_noisy.smoothness())   # large step -> fast saturation
    r = fed.run(lsr_noisy, cfg, gamma=g, iters=1500, key=KEY, batch=1)
    opt = float(lsr_noisy.global_loss(lsr_noisy.solve_opt()))
    tail_exc = float(lsr_noisy.global_loss(jnp.asarray(r.w_tail_avg))) - opt
    last_exc = float(np.mean(r.losses[-200:])) - opt
    assert tail_exc <= last_exc * 1.05 + 1e-8, (tail_exc, last_exc)


def test_gamma_max_formulas(lsr_noisy):
    """No-compression gamma_max recovers ~1/L-scale SGD bound (Table 3)."""
    sgd = art.variant_config("sgd", 20, 10)
    g_sgd = fed.gamma_max(lsr_noisy, sgd)
    L = lsr_noisy.smoothness()
    assert 0.2 / L < g_sgd <= 1.0 / L
    bi = art.variant_config("artemis", 20, 10)
    assert fed.gamma_max(lsr_noisy, bi) < g_sgd   # compression shrinks gamma_max


def test_catchup_bit_metering():
    """Remark 3: an absent worker pays missed*M2 bits on return, capped at
    M1 (the full model) once it has been away longer than floor(M1/M2)."""
    prob, _ = fed.make_lsr_problem(KEY, n_workers=8, n_per=50, d=20, noise=0.0)
    # full participation vs p=0.3: the PP run pays catch-up on top of uplink
    cfg_full = art.variant_config("artemis", 20, 8, p=1.0)
    cfg_pp = art.variant_config("artemis", 20, 8, p=0.3)
    r_full = fed.run(prob, cfg_full, gamma=0.01, iters=100, key=KEY, batch=4)
    r_pp = fed.run(prob, cfg_pp, gamma=0.01, iters=100, key=KEY, batch=4)
    # fewer active workers -> less uplink, but catch-up bits are bounded by
    # M1 per return, so total stays within [0, full-participation total]
    assert 0 < r_pp.bits[-1] < r_full.bits[-1] * 1.5
    # catch-up bound sanity: per-round bits never exceed N*(uplink + M1)
    per_round = np.diff(r_pp.bits)
    c_up, _ = cfg_pp.compressors()
    cap = 8 * (c_up.bits(20) + comp.FP_BITS * 20)
    assert (per_round <= cap + 1e-6).all()
