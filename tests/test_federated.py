"""Integration tests: the paper's convergence claims on the simulator.

Variant loops ride ONE ``run_sweep`` grid each (single compile per test);
the expensive problems come from session-scoped fixtures in conftest.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import artemis as art, federated as fed
from repro.core import compression as comp
from repro.core import sweep as sw

KEY = jax.random.PRNGKey(42)


@pytest.fixture(scope="module")
def lsr_noiseless(lsr_noiseless_session):
    return lsr_noiseless_session


@pytest.fixture(scope="module")
def lsr_noisy(lsr_noisy_session):
    return lsr_noisy_session


def test_linear_convergence_sigma_star_zero(lsr_noiseless):
    """Thm 1: sigma_*=0 => linear convergence for ALL variants (E=0 floor).

    Each variant runs at its own gamma_max: the grid is (4 variants x 4
    gammas) and the assertion reads the matched diagonal."""
    variants = ["sgd", "qsgd", "diana", "biqsgd"]
    cfgs = [art.variant_config(v, 20, 10) for v in variants]
    gs = [fed.gamma_max(lsr_noiseless, c) for c in cfgs]
    res = sw.run_sweep(lsr_noiseless, cfgs, gs, [42], iters=400, batch=8,
                       eval_every=100)
    for vi, v in enumerate(variants):
        assert res.losses[vi, vi, 0, -1] < 1e-5, (v, res.losses[vi, vi, 0, -1])


def test_saturation_ordering_sigma_star_nonzero(lsr_noisy):
    """Fig 3a: with sigma_* != 0 all algorithms saturate; double compression
    saturates higher than single, higher than SGD (at a shared step size)."""
    gamma = 1.0 / (4 * lsr_noisy.smoothness())
    variants = ["sgd", "qsgd", "biqsgd"]
    cfgs = [art.variant_config(v, 20, 10) for v in variants]
    res = sw.run_sweep(lsr_noisy, cfgs, [gamma], [42], iters=600, batch=1,
                       eval_every=5)
    floors = {v: float(np.mean(res.losses[vi, 0, 0, -20:]))
              for vi, v in enumerate(variants)}
    opt = float(lsr_noisy.global_loss(lsr_noisy.solve_opt()))
    assert floors["sgd"] - opt < floors["qsgd"] - opt < floors["biqsgd"] - opt


def test_memory_helps_non_iid(logistic_session):
    """Fig 3b / S9: non-i.i.d. full-batch (sigma_*=0): memory converges
    linearly, memoryless bidirectional saturates at a high level."""
    prob = logistic_session
    gamma = 1.0 / (2 * prob.smoothness())
    cfgs = [art.variant_config(v, 2, 10) for v in ["artemis", "biqsgd"]]
    res = sw.run_sweep(prob, cfgs, [gamma], [42], iters=800, full_batch=True,
                       eval_every=100)
    opt = float(prob.global_loss(prob.solve_opt()))
    exc_mem = res.losses[0, 0, 0, -1] - opt
    exc_nomem = res.losses[1, 0, 0, -1] - opt
    assert exc_mem < exc_nomem / 5, (exc_mem, exc_nomem)


def test_pp2_beats_pp1(logistic_session):
    """Fig 5/6: partial participation, full gradients, non-iid: PP1 saturates,
    PP2 converges linearly."""
    prob = logistic_session
    gamma = 1.0 / (2 * prob.smoothness())
    cfgs = [art.ArtemisConfig(dim=2, n_workers=10, up="identity",
                              dwn="identity", alpha=0.5, p=0.5, pp_mode=mode)
            for mode in ["pp1", "pp2"]]
    res = sw.run_sweep(prob, cfgs, [gamma], [42], iters=800, full_batch=True,
                       eval_every=10)
    opt = float(prob.global_loss(prob.solve_opt()))
    exc = {m: float(np.mean(res.losses[mi, 0, 0, -5:])) - opt
           for mi, m in enumerate(["pp1", "pp2"])}
    assert exc["pp2"] < exc["pp1"] / 5, exc


def test_bidirectional_bit_savings(lsr_noiseless):
    """App A.1: bi-compression ~ O(sqrt(d) log d) per direction vs O(d)."""
    cfgs = [art.variant_config(v, 20, 10) for v in ["sgd", "artemis"]]
    res = sw.run_sweep(lsr_noiseless, cfgs, [0.01], [42], iters=50, batch=4,
                       eval_every=50)
    assert res.bits[1, 0, 0, -1] < res.bits[0, 0, 0, -1] / 2


def test_polyak_ruppert_tail_average(lsr_noisy):
    """Thm 2 (qualitatively): once in the stationary regime, averaging reduces
    the excess loss vs the oscillating last iterate."""
    cfg = art.variant_config("qsgd", 20, 10)
    g = 1.0 / (3 * lsr_noisy.smoothness())   # large step -> fast saturation
    r = fed.run(lsr_noisy, cfg, gamma=g, iters=1500, key=KEY, batch=1)
    opt = float(lsr_noisy.global_loss(lsr_noisy.solve_opt()))
    tail_exc = float(lsr_noisy.global_loss(jnp.asarray(r.w_tail_avg))) - opt
    last_exc = float(np.mean(r.losses[-200:])) - opt
    assert tail_exc <= last_exc * 1.05 + 1e-8, (tail_exc, last_exc)


def test_gamma_max_formulas(lsr_noisy):
    """No-compression gamma_max recovers ~1/L-scale SGD bound (Table 3)."""
    sgd = art.variant_config("sgd", 20, 10)
    g_sgd = fed.gamma_max(lsr_noisy, sgd)
    L = lsr_noisy.smoothness()
    assert 0.2 / L < g_sgd <= 1.0 / L
    bi = art.variant_config("artemis", 20, 10)
    assert fed.gamma_max(lsr_noisy, bi) < g_sgd   # compression shrinks gamma_max


def test_catchup_bit_metering():
    """Remark 3: an absent worker pays missed*M2 bits on return, capped at
    M1 (the full model) once it has been away > floor(M1/M2) rounds."""
    prob, _ = fed.make_lsr_problem(KEY, n_workers=8, n_per=50, d=20, noise=0.0)
    # full participation vs p=0.3: the PP run pays catch-up on top of uplink
    cfgs = [art.variant_config("artemis", 20, 8, p=1.0),
            art.variant_config("artemis", 20, 8, p=0.3)]
    res = sw.run_sweep(prob, cfgs, [0.01], [42], iters=100, batch=4,
                       eval_every=1)
    bits_full, bits_pp = res.bits[0, 0, 0], res.bits[1, 0, 0]
    # fewer active workers -> less uplink, but catch-up bits are bounded by
    # M1 per return, so total stays within [0, full-participation total]
    assert 0 < bits_pp[-1] < bits_full[-1] * 1.5
    # catch-up bound sanity: per-round bits never exceed N*(uplink + M1)
    per_round = np.diff(bits_pp)
    c_up, _ = cfgs[1].compressors()
    cap = 8 * (c_up.bits(20) + comp.FP_BITS * 20)
    assert (per_round <= cap + 1e-6).all()
