"""Unit + property tests for compression operators (Assumption 5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.prop import given, settings, st

from repro.core import compression as comp

KEY = jax.random.PRNGKey(0)


def _mc_unbiasedness_and_variance(c, x, n_samples=2000, tol=0.08):
    keys = jax.random.split(KEY, n_samples)
    outs = jax.vmap(lambda k: c(k, x))(keys)
    mean = jnp.mean(outs, axis=0)
    err = jnp.mean(jnp.sum((outs - x[None]) ** 2, axis=-1))
    nx2 = float(jnp.sum(x**2))
    # unbiased: E[C(x)] = x
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x),
                               atol=tol * np.sqrt(nx2 / x.size) * 3 + 1e-6)
    # variance bound: E||C(x)-x||^2 <= omega ||x||^2 (+ mc slack)
    assert float(err) <= c.omega * nx2 * (1 + tol) + 1e-6, (float(err), c.omega * nx2)


@pytest.mark.parametrize("name,kwargs", [
    ("squant", {"s": 1}),
    ("squant", {"s": 4}),
    ("tile_squant", {"s": 1, "tile": 8}),
    ("sparsify", {"q": 0.5}),
    ("sparsify", {"q": 0.25}),
    ("identity", {}),
])
def test_assumption5(name, kwargs):
    d = 32
    x = jax.random.normal(jax.random.PRNGKey(7), (d,))
    c = comp.make_compressor(name, d, **kwargs)
    _mc_unbiasedness_and_variance(c, x)


def test_identity_exact():
    c = comp.identity()
    x = jnp.arange(10.0)
    assert jnp.array_equal(c(KEY, x), x)
    assert c.omega == 0.0


def test_squant_zero_vector():
    c = comp.squant(16, s=1)
    out = c(KEY, jnp.zeros(16))
    assert jnp.array_equal(out, jnp.zeros(16))


def test_squant_levels():
    """Outputs lie on the s-quantization grid sign*norm*l/s."""
    d, s = 64, 4
    x = jax.random.normal(jax.random.PRNGKey(3), (d,))
    c = comp.squant(d, s)
    out = np.asarray(c(KEY, x))
    norm = float(jnp.linalg.norm(x))
    lv = np.abs(out) / norm * s
    np.testing.assert_allclose(lv, np.round(lv), atol=1e-4)


def test_sparsify_support():
    d = 100
    x = jax.random.normal(jax.random.PRNGKey(5), (d,))
    c = comp.sparsify(0.3)
    out = np.asarray(c(KEY, x))
    nz = out != 0
    np.testing.assert_allclose(out[nz], np.asarray(x)[nz] / 0.3, rtol=1e-5)


def test_omega_formulas():
    assert comp.squant_omega(100, 1) == pytest.approx(10.0)   # sqrt(d)/s branch
    assert comp.squant_omega(4, 4) == pytest.approx(0.25)     # d/s^2 branch
    assert comp.sparsify(0.25).omega == pytest.approx(3.0)    # 1/q - 1


def test_bits_ordering():
    """1-quantization ~ O(sqrt(d) log d) bits << 32 d (paper A.1)."""
    d = 4096
    c = comp.squant(d, s=1)
    assert c.bits(d) < 32 * d / 4


def test_shapes_preserved():
    c = comp.tile_squant(tile=128, s=1)
    x = jax.random.normal(KEY, (3, 5, 7))
    assert c(KEY, x).shape == (3, 5, 7)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 200), st.integers(1, 8), st.integers(0, 10**6))
def test_squant_grid_property(d, s, seed):
    """Property: every squant output coordinate is a valid grid point with
    level <= ceil(s) + 1 and correct sign."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    c = comp.squant(d, s)
    out = np.asarray(c(jax.random.PRNGKey(seed + 1), x))
    norm = float(jnp.linalg.norm(x))
    lv = np.abs(out) / norm * s
    np.testing.assert_allclose(lv, np.round(lv), atol=1e-3)
    assert (lv <= s + 1 + 1e-3).all()
    sign_mismatch = (np.sign(out) != 0) & (np.sign(out) != np.sign(np.asarray(x)))
    assert not sign_mismatch.any()


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["squant", "tile_squant", "sparsify"]),
       st.integers(0, 10**6))
def test_scale_equivariance(name, seed):
    """C(c*x) distribution == c*C(x) for positive scalars (same key)."""
    d = 32
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    c = comp.make_compressor(name, d)
    k = jax.random.PRNGKey(seed + 13)
    a = np.asarray(c(k, 3.0 * x))
    b = np.asarray(3.0 * c(k, x))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
