"""Layer-level correctness: chunked attention/xent vs naive, scan chunking of
SSM/RG-LRU vs step-by-step recurrence, RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.prop import given, settings, st

from repro.models import layers as L
from repro.models import rglru as RG
from repro.models import ssm as SSM

KEY = jax.random.PRNGKey(0)


# -- attention -----------------------------------------------------------------

def _naive_attention(q, k, v, causal=True, window=0):
    b, s, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    kk = jnp.repeat(k, rep, 2)
    vv = jnp.repeat(v, rep, 2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / jnp.sqrt(d)
    i = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i[:, None] >= i[None, :]
    if window:
        mask &= (i[:, None] - i[None, :]) < window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("q_chunk", [8, 32, 128])
def test_chunked_attention_matches_naive(window, q_chunk):
    b, s, h, kv, d = 2, 64, 4, 2, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d))
    pos = jnp.arange(s)[None].repeat(b, 0)
    out = L.attention(q, k, v, pos, pos, window=window, q_chunk=q_chunk)
    ref = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


def test_non_causal_attention():
    b, s, h, d = 1, 16, 2, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    pos = jnp.arange(s)[None].repeat(b, 0)
    out = L.attention(q, k, v, pos, pos, causal=False, q_chunk=8)
    ref = _naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


# -- rope -----------------------------------------------------------------------

def test_rope_preserves_norm():
    x = jax.random.normal(KEY, (2, 8, 4, 16))
    pos = jnp.arange(8)[None].repeat(2, 0)
    y = L.rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    d = 16
    q = jax.random.normal(KEY, (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))

    def dot(i, j):
        qi = L.rope(q, jnp.array([[i]]))
        kj = L.rope(k, jnp.array([[j]]))
        return float(jnp.sum(qi * kj))

    assert dot(5, 3) == pytest.approx(dot(9, 7), rel=1e-4)
    assert dot(5, 3) != pytest.approx(dot(5, 4), rel=1e-3)


# -- chunked xent ----------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 40), st.integers(4, 64), st.integers(0, 1000))
def test_chunked_xent_matches_naive(s, v, seed):
    b, d = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(seed + 2), (b, s), 0, v)
    # mask a few labels
    labels = labels.at[:, 0].set(-1)
    out = L.chunked_xent(x, w, labels, chunk=16)
    logits = x @ w
    logp = jax.nn.log_softmax(logits, -1)
    gold = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    valid = labels >= 0
    ref = -jnp.sum(gold * valid) / jnp.sum(valid)
    np.testing.assert_allclose(float(out), float(ref), rtol=1e-4)


# -- SSM / RG-LRU: chunked scan == step-by-step recurrence -------------------------

def test_mamba_chunked_equals_decode_chain():
    d, b, s = 32, 2, 64
    p = SSM.init_mamba(KEY, d)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    full, _ = SSM.mamba_apply(p, x, chunk=16)
    st_ = SSM.init_mamba_state(b, d)
    outs = []
    for t in range(s):
        y, st_ = SSM.mamba_apply(p, x[:, t:t + 1], state=st_)
        outs.append(y)
    step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=2e-3,
                               atol=2e-4)


def test_rglru_chunked_equals_decode_chain():
    d, lw, b, s = 32, 32, 2, 64
    p = RG.init_rglru(KEY, d, lw)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    full, _ = RG.rglru_apply(p, x, chunk=16)
    st_ = RG.init_rglru_state(b, lw)
    outs = []
    for t in range(s):
        y, st_ = RG.rglru_apply(p, x[:, t:t + 1], state=st_)
        outs.append(y)
    step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=2e-3,
                               atol=2e-4)


def test_rglru_stability():
    """|a_t| < 1: long sequences cannot blow up."""
    d = lw = 16
    p = RG.init_rglru(KEY, d, lw)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, d))
    y, _ = RG.rglru_apply(p, x, chunk=64)
    assert np.isfinite(np.asarray(y)).all()
    assert float(jnp.max(jnp.abs(y))) < 1e3


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(0, 100))
def test_mamba_state_invariant_chunks(nc, seed):
    """Property: output independent of the chunk size used for the scan."""
    d, b = 16, 1
    s = 32 * nc
    p = SSM.init_mamba(jax.random.PRNGKey(seed), d)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, d))
    a, _ = SSM.mamba_apply(p, x, chunk=8)
    c, _ = SSM.mamba_apply(p, x, chunk=s)       # single chunk
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-3,
                               atol=2e-4)
