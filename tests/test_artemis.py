"""Tests of the Artemis round: variant semantics, PP1/PP2, memory dynamics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import artemis as art

KEY = jax.random.PRNGKey(0)
N, D = 8, 16


def _round(cfg, grads, state=None, active=None, key=KEY):
    state = art.init_state(cfg) if state is None else state
    return art.artemis_round(cfg, state, grads, key, active)


def test_sgd_variant_is_plain_mean():
    cfg = art.variant_config("sgd", D, N)
    g = jax.random.normal(KEY, (N, D))
    omega, st, _ = _round(cfg, g)
    np.testing.assert_allclose(np.asarray(omega), np.asarray(jnp.mean(g, 0)), rtol=1e-6)
    assert jnp.array_equal(st.h, jnp.zeros((N, D)))   # no memory with alpha=0


def test_memory_recursion():
    """h' = h + alpha*C(g - h); with identity compressor: h' = (1-a)h + a g."""
    cfg = art.ArtemisConfig(dim=D, n_workers=N, up="identity", dwn="identity", alpha=0.25)
    g = jax.random.normal(KEY, (N, D))
    h0 = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    st = art.init_state(cfg)._replace(h=h0, hbar=jnp.mean(h0, 0))
    omega, st2, _ = _round(cfg, g, state=st)
    np.testing.assert_allclose(np.asarray(st2.h), np.asarray(0.75 * h0 + 0.25 * g), rtol=1e-5)
    # full participation, identity: omega == mean(g)
    np.testing.assert_allclose(np.asarray(omega), np.asarray(jnp.mean(g, 0)), rtol=1e-5)


def test_default_alpha():
    cfg = art.variant_config("artemis", D, N, s=1)
    c_up, _ = cfg.compressors()
    assert cfg.resolved_alpha() == pytest.approx(1.0 / (2 * (c_up.omega + 1)))
    assert art.variant_config("sgd", D, N).resolved_alpha() == 0.0


def test_unbiased_aggregate():
    """E[omega] == mean(g) over compression randomness (full participation)."""
    cfg = art.variant_config("artemis", D, N, s=1)
    g = jax.random.normal(KEY, (N, D))
    st = art.init_state(cfg)
    keys = jax.random.split(jax.random.PRNGKey(2), 3000)
    omegas = jax.vmap(lambda k: art.artemis_round(cfg, st, g, k)[0])(keys)
    np.testing.assert_allclose(np.asarray(jnp.mean(omegas, 0)),
                               np.asarray(jnp.mean(g, 0)), atol=0.15)


def test_pp2_uses_memory_of_inactive():
    """PP2's ghat includes hbar built from ALL workers even when some inactive."""
    cfg = art.ArtemisConfig(dim=D, n_workers=N, up="identity", dwn="identity",
                            alpha=0.5, p=0.5, pp_mode="pp2")
    g = jnp.ones((N, D))
    st0 = art.init_state(cfg)
    # round 1: all active -> hbar becomes alpha*mean(delta) = 0.5*1
    omega1, st1, _ = _round(cfg, g, state=st0, active=jnp.ones(N))
    np.testing.assert_allclose(np.asarray(st1.hbar), 0.5 * np.ones(D), rtol=1e-6)
    # round 2: NO workers active -> ghat = hbar exactly
    omega2, st2, _ = _round(cfg, g, state=st1, active=jnp.zeros(N))
    np.testing.assert_allclose(np.asarray(omega2), np.asarray(st1.hbar), rtol=1e-6)
    # inactive memories untouched
    np.testing.assert_allclose(np.asarray(st2.h), np.asarray(st1.h))


def test_pp1_vs_pp2_full_participation_equal():
    """With p=1 and all active, PP1 == PP2 (identical ghat)."""
    g = jax.random.normal(KEY, (N, D))
    outs = {}
    for mode in ["pp1", "pp2"]:
        cfg = art.ArtemisConfig(dim=D, n_workers=N, up="identity", dwn="identity",
                                alpha=0.3, p=1.0, pp_mode=mode)
        st = art.init_state(cfg)
        # two rounds to engage memories
        omega, st, _ = _round(cfg, g, state=st)
        omega, st, _ = _round(cfg, 2 * g, state=st, key=jax.random.PRNGKey(9))
        outs[mode] = np.asarray(omega)
    np.testing.assert_allclose(outs["pp1"], outs["pp2"], rtol=1e-5)


def test_pp1_noise_at_optimum():
    """PP1 with p<1 has non-zero variance even with zero-mean heterogeneous
    gradients at the optimum (paper Section 4's failure mode);
    PP2 with converged memory has none."""
    # 'gradients at optimum': per-worker fixed vectors summing to zero
    g = jax.random.normal(KEY, (N, D))
    g = g - jnp.mean(g, axis=0, keepdims=True)     # sum_i grad_i(w*) = 0
    p = 0.5
    base = dict(dim=D, n_workers=N, up="identity", dwn="identity", alpha=0.5, p=p)
    # memories converged to h_i = grad_i(w*)
    var = {}
    for mode in ["pp1", "pp2"]:
        cfg = art.ArtemisConfig(pp_mode=mode, **base)
        st = art.init_state(cfg)._replace(h=g, hbar=jnp.mean(g, 0))
        keys = jax.random.split(KEY, 500)
        def one(k):
            act = (jax.random.uniform(k, (N,)) < p).astype(jnp.float32)
            om, _, _ = art.artemis_round(cfg, st, g, jax.random.fold_in(k, 1), act)
            return jnp.sum(om ** 2)
        var[mode] = float(jnp.mean(jax.vmap(one)(keys)))
    assert var["pp2"] < 1e-10
    assert var["pp1"] > 1e-2


def test_error_feedback_accumulates():
    cfg = art.ArtemisConfig(dim=D, n_workers=N, up="squant", dwn="identity",
                            alpha=0.0, error_feedback=True, up_kwargs={"s": 1})
    g = jax.random.normal(KEY, (N, D))
    _, st, _ = _round(cfg, g)
    assert float(jnp.sum(st.e ** 2)) > 0.0


def test_bits_stats():
    cfg = art.variant_config("artemis", D, N, s=1)
    _, _, stats = _round(cfg, jnp.ones((N, D)))
    assert stats["uplink_bits"] > 0 and stats["dwnlink_bits"] > 0
    sgd = art.variant_config("sgd", D, N)
    _, _, s2 = _round(sgd, jnp.ones((N, D)))
    assert stats["uplink_bits"] < s2["uplink_bits"]   # compression saves bits
