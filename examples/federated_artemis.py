"""Paper reproduction walkthrough (Artemis, Philippenko & Dieuleveut 2020).

Runs the paper's four headline experiments on the federated simulator and
prints the claims being validated:

  1. Fig 3a  — sigma_* != 0, i.i.d.: every variant saturates; double
               compression saturates above single, above SGD (Thm 1 / Thm 3).
  2. Fig S8  — sigma_* == 0: LINEAR convergence for all variants.
  3. Fig 3b  — non-i.i.d., full batch: memory removes the B^2 term — Artemis
               converges linearly where Bi-QSGD stalls.
  4. Fig 5/6 — partial participation: PP1 saturates, the novel PP2 does not.

    PYTHONPATH=src python examples/federated_artemis.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import artemis as art
from repro.core import federated as fed

KEY = jax.random.PRNGKey(0)
N, D = 20, 20


def exp1_saturation():
    print("\n=== 1. Fig 3a: saturation under sigma_* != 0 (i.i.d. LSR) ===")
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.4)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 0.8 * fed.gamma_max(prob, art.variant_config("artemis", D, N))
    for v in ["sgd", "qsgd", "diana", "biqsgd", "artemis"]:
        r = fed.run(prob, art.variant_config(v, D, N), gamma=gamma, iters=3000,
                    key=KEY, batch=1)
        sat = float(np.mean(r.losses[-300:])) - opt
        print(f"  {v:8s} saturation = {sat:.2e}")
    print("  -> ordering sgd < one-way < two-way, as Thm 1's E predicts")


def exp2_linear():
    print("\n=== 2. Fig S8: linear convergence when sigma_* == 0 ===")
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.0)
    for v in ["sgd", "qsgd", "biqsgd", "artemis"]:
        cfg = art.variant_config(v, D, N)
        g = fed.gamma_max(prob, cfg)
        r = fed.run(prob, cfg, gamma=g, iters=600, key=KEY, batch=8)
        print(f"  {v:8s} F(w_600)-F* = {r.losses[-1]:.2e}  (gamma_max={g:.4f})")
    print("  -> all reach ~machine precision: threshold E ∝ sigma_*^2 = 0")


def exp3_memory():
    print("\n=== 3. Fig 3b: heterogeneity — memory removes B^2 ===")
    prob = fed.make_logistic_problem(jax.random.PRNGKey(3), n_workers=N,
                                     n_per=200, d=2)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (2 * prob.smoothness())
    for v in ["biqsgd", "artemis"]:
        r = fed.run(prob, art.variant_config(v, 2, N), gamma=gamma, iters=800,
                    key=KEY, full_batch=True)
        tag = "memoryless" if v == "biqsgd" else "with memory"
        print(f"  {v:8s} ({tag:11s}) excess = {r.losses[-1] - opt:.2e}")
    print("  -> identical compression, only the memory differs")


def exp4_pp():
    print("\n=== 4. Fig 5/6: partial participation, PP1 vs PP2 (p=0.5) ===")
    prob = fed.make_logistic_problem(jax.random.PRNGKey(5), n_workers=N,
                                     n_per=200, d=2)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (2 * prob.smoothness())
    for mode in ["pp1", "pp2"]:
        cfg = art.variant_config("artemis", 2, N, p=0.5, pp_mode=mode)
        r = fed.run(prob, cfg, gamma=gamma, iters=800, key=KEY, full_batch=True)
        print(f"  {mode}: excess = {float(np.mean(r.losses[-50:])) - opt:.2e}")
    print("  -> PP1 saturates at (1-p)B^2/(Np); PP2 (the paper's novel "
          "algorithm) converges linearly")


if __name__ == "__main__":
    exp1_saturation()
    exp2_linear()
    exp3_memory()
    exp4_pp()
