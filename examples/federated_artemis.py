"""Paper reproduction walkthrough (Artemis, Philippenko & Dieuleveut 2020).

Runs the paper's four headline experiments on the federated simulator and
prints the claims being validated:

  1. Fig 3a  — sigma_* != 0, i.i.d.: every variant saturates; double
               compression saturates above single, above SGD (Thm 1 / Thm 3).
  2. Fig S8  — sigma_* == 0: LINEAR convergence for all variants.
  3. Fig 3b  — non-i.i.d., full batch: memory removes the B^2 term — Artemis
               converges linearly where Bi-QSGD stalls.
  4. Fig 5/6 — partial participation: PP1 saturates, the novel PP2 does not.
  5. faults  — beyond the paper's assumptions: NaN blowups, wire bit-flips
               and sticky (Markov) availability, healed by server scrubbing
               + the divergence sentinel (DESIGN.md §8).

Every experiment runs its whole variant grid through the batched sweep
engine (core.sweep.run_sweep): one compiled program per experiment instead
of one retrace per variant.

    PYTHONPATH=src python examples/federated_artemis.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import artemis as art
from repro.core import faults
from repro.core import federated as fed
from repro.core import sweep as sw

KEY = jax.random.PRNGKey(0)
N, D = 20, 20


def exp1_saturation():
    print("\n=== 1. Fig 3a: saturation under sigma_* != 0 (i.i.d. LSR) ===")
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.4)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 0.8 * fed.gamma_max(prob, art.variant_config("artemis", D, N))
    variants = ["sgd", "qsgd", "diana", "biqsgd", "artemis"]
    cfgs = [art.variant_config(v, D, N) for v in variants]
    res = sw.run_sweep(prob, cfgs, [gamma], [0], iters=3000, batch=1,
                       eval_every=10)
    for vi, v in enumerate(variants):
        sat = float(np.mean(res.losses[vi, 0, 0, -30:])) - opt
        print(f"  {v:8s} saturation = {sat:.2e}")
    print(f"  (grid of {len(cfgs)} variants compiled {res.traces}x)")
    print("  -> ordering sgd < one-way < two-way, as Thm 1's E predicts")


def exp2_linear():
    print("\n=== 2. Fig S8: linear convergence when sigma_* == 0 ===")
    prob, _ = fed.make_lsr_problem(KEY, n_workers=N, n_per=200, d=D, noise=0.0)
    variants = ["sgd", "qsgd", "biqsgd", "artemis"]
    cfgs = [art.variant_config(v, D, N) for v in variants]
    gs = [fed.gamma_max(prob, c) for c in cfgs]
    # per-variant gamma_max: run the (variant x gamma) grid, read the diagonal
    res = sw.run_sweep(prob, cfgs, gs, [0], iters=600, batch=8, eval_every=100)
    for vi, v in enumerate(variants):
        print(f"  {v:8s} F(w_600)-F* = {res.losses[vi, vi, 0, -1]:.2e}  "
              f"(gamma_max={gs[vi]:.4f})")
    print("  -> all reach ~machine precision: threshold E ∝ sigma_*^2 = 0")


def exp3_memory():
    print("\n=== 3. Fig 3b: heterogeneity — memory removes B^2 ===")
    prob = fed.make_logistic_problem(jax.random.PRNGKey(3), n_workers=N,
                                     n_per=200, d=2)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (2 * prob.smoothness())
    variants = ["biqsgd", "artemis"]
    cfgs = [art.variant_config(v, 2, N) for v in variants]
    res = sw.run_sweep(prob, cfgs, [gamma], [0], iters=800, full_batch=True,
                       eval_every=100)
    for vi, v in enumerate(variants):
        tag = "memoryless" if v == "biqsgd" else "with memory"
        print(f"  {v:8s} ({tag:11s}) excess = "
              f"{res.losses[vi, 0, 0, -1] - opt:.2e}")
    print("  -> identical compression, only the memory differs")


def exp4_pp():
    print("\n=== 4. Fig 5/6: partial participation, PP1 vs PP2 (p=0.5) ===")
    prob = fed.make_logistic_problem(jax.random.PRNGKey(5), n_workers=N,
                                     n_per=200, d=2)
    opt = float(prob.global_loss(prob.solve_opt()))
    gamma = 1.0 / (2 * prob.smoothness())
    modes = ["pp1", "pp2"]
    cfgs = [art.variant_config("artemis", 2, N, p=0.5, pp_mode=m)
            for m in modes]
    res = sw.run_sweep(prob, cfgs, [gamma], [0], iters=800, full_batch=True,
                       eval_every=10)
    for mi, mode in enumerate(modes):
        exc = float(np.mean(res.losses[mi, 0, 0, -5:])) - opt
        print(f"  {mode}: excess = {exc:.2e}")
    print("  -> PP1 saturates at (1-p)B^2/(Np); PP2 (the paper's novel "
          "algorithm) converges linearly")


def exp5_faults():
    print("\n=== 5. beyond Assumption 6: faults + the self-healing server ===")
    prob, _ = fed.make_lsr_problem(jax.random.PRNGKey(9), n_workers=N,
                                   n_per=200, d=D, noise=0.4)
    gamma = 0.5 * fed.gamma_max(prob, art.variant_config("artemis", D, N))
    base = art.variant_config("artemis", D, N, p=0.5)
    grid = {
        "clean (i.i.d. p=0.5)": None,
        "sticky markov p_stay=0.9": faults.FaultConfig(p_stay=0.9),
        "nan blowups, scrubbed": faults.FaultConfig(blowup_rate=0.2,
                                                    scrub=True),
        "bit-flips + sentinel": faults.FaultConfig(bitflip_rate=0.005,
                                                   scrub=True, sentinel=20.0,
                                                   backoff=0.8),
    }
    cfgs = [dataclasses.replace(base, faults=fc) for fc in grid.values()]
    res = sw.run_sweep(prob, cfgs, [gamma], [0], iters=1500, batch=1,
                       eval_every=10)
    for fi, name in enumerate(grid):
        loss = float(res.losses[fi, 0, 0, -1])
        rb = int(res.rollbacks[fi, 0, 0])
        print(f"  {name:26s} final loss = {loss:.3f}  rollbacks = {rb}")
    print("  -> every faulted cell stays finite and tracks the clean run: "
          "corrupt payloads are reclassified as non-participation (PP2 "
          "zero-scale), divergences roll back with gamma backoff")


if __name__ == "__main__":
    exp1_saturation()
    exp2_linear()
    exp3_memory()
    exp4_pp()
    exp5_faults()
