"""Batched serving demo: greedy decode with a ring-buffer KV cache.

Serves the reduced mixtral (MoE + sliding window) so the interesting decode
machinery — expert routing per token, O(window) cache — is exercised.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as S


if __name__ == "__main__":
    S.main(["--arch", "mixtral-8x22b", "--reduced",
            "--batch", "4", "--prompt-len", "16", "--gen", "24"])
