"""End-to-end LM training driver with Artemis compression.

Default runs a ~20M-parameter GQA transformer ("100M-class", scaled to this
CPU container) for 300 steps on the synthetic bigram corpus — the loss drops
from ~log(vocab) toward the corpus's bigram entropy floor. Pass --full-100m
for the real ~100M config (slow on CPU; sized for a single TPU host).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full-100m]
"""
import argparse
import dataclasses

from repro.launch import train as T
from repro.models.config import ModelConfig
import repro.configs as configs


def small_cfg(full: bool) -> ModelConfig:
    if full:   # ~100M params
        return ModelConfig(name="lm-100m", family="dense", n_layers=12,
                           d_model=768, n_heads=12, n_kv=4, d_ff=3072,
                           vocab=8192, activation="silu", q_chunk=256,
                           xent_chunk=256, remat=False)
    return ModelConfig(name="lm-20m", family="dense", n_layers=6,
                       d_model=384, n_heads=6, n_kv=2, d_ff=1536,
                       vocab=4096, activation="silu", q_chunk=128,
                       xent_chunk=128, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--dist", default="artemis")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = small_cfg(args.full_100m)
    # register on the fly so launch.train can find it
    mod_name = "lm_example"
    import sys
    import types
    mod = types.ModuleType(f"repro.configs.{mod_name}")
    mod.CONFIG = cfg
    mod.REDUCED = cfg
    sys.modules[f"repro.configs.{mod_name}"] = mod
    configs.ARCHS[cfg.name] = mod_name

    logs = T.main([
        "--arch", cfg.name, "--steps", str(args.steps), "--batch", "16",
        "--seq", "256", "--dist", args.dist, "--workers", "data",
        "--optimizer", "adam", "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])
    drop = logs[0]["loss"] - logs[-1]["loss"]
    print(f"\nloss {logs[0]['loss']:.3f} -> {logs[-1]['loss']:.3f} "
          f"(dropped {drop:.2f} nats over {args.steps} steps, "
          f"checkpoints in {args.ckpt_dir})")
    assert drop > 0.5, "training did not learn"


if __name__ == "__main__":
    main()
