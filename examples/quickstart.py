"""Quickstart: train a tiny transformer with bidirectional-compressed
gradient aggregation (Artemis) on whatever devices this host has.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import dist
from repro.data.synthetic import TokenStream, TokenStreamConfig
from repro.launch import mesh as M
from repro.models.model import build_model
from repro.obs import EventLog, span
from repro.optim import adam


def main():
    cfg = configs.get_config("starcoder2-7b", reduced=True)
    model = build_model(cfg)
    mesh = M.make_host_mesh()

    # Artemis over the 'data' axis: uplink int8 ring + memory, zero-byte
    # downlink broadcast. With one device this degrades to plain compression
    # noise on the gradient — still exercises the full code path.
    # telemetry=True attaches a psum'd `obs` dict to the step metrics
    # (wire bytes on the ring, participation, scrub/blowup counts) at no
    # cost to the math — the trajectory is bitwise identical either way.
    dcfg = dist.DistConfig(worker_axes=("data",), variant="artemis", s=4,
                           telemetry=True)

    init_state, step_fn = dist.make_train_step(model, adam(3e-3), dcfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    stream = TokenStream(TokenStreamConfig(vocab=cfg.vocab, seq_len=128, batch=8))

    # repro.obs: console output through the schema-checked event sink
    # (pass a path instead of None to also persist JSONL)
    log = EventLog(None)
    with jax.set_mesh(mesh):
        state = init_state(params)
        jstep = jax.jit(step_fn)
        for i in range(50):
            with span("quickstart/step"):
                state, (loss, m) = jstep(state, stream.batch_at(i))
            if i % 10 == 0 or i == 49:
                log.emit("train_step", step=i, loss=round(float(loss), 4),
                         wall_s=0.0, wire_bytes=float(m["obs"]["wire_bytes"]))
    log.emit("note", text="done — loss should have dropped by >1 nat.")


if __name__ == "__main__":
    main()
